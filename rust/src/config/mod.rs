//! JSON config system: declarative job + environment descriptions under
//! `configs/`, loadable from the CLI (`cloudless train --config <file>`).
//!
//! Schema (all fields optional unless noted):
//!
//! ```json
//! {
//!   "model": "lenet",                  // required
//!   "epochs": 10,
//!   "lr": 0.03,
//!   "seed": 42,
//!   "n_train": 4096, "n_eval": 1024,
//!   "strategy": "asgd-ga",             // asgd | asgd-ga | ama | ma | sma
//!   "sync_freq": 4,
//!   "compression": "topk:0.25",        // none | topk[:ratio] | q8
//!   "topology": "ring",                // ring | hierarchical | bandwidth-tree
//!   "scheduling": "elastic",           // elastic | greedy
//!   "elastic": {"enabled": true,       // live re-scheduling control loop
//!               "interval_s": 60, "hysteresis": 0.2,
//!               "bw_threshold": 0.5, "smoothing": 0.5},
//!   "wan_lanes": true,                 // WAN priority lanes (default false)
//!   "relay_routes": true,              // 2-hop relay routes (default false)
//!   "auto_compression": true,          // controller picks per-link codecs
//!   "multijob": {"jobs": 6,            // multi-job fleet (exp --id multijob)
//!                "mean_interarrival_s": 0, "policy": "fair-share",
//!                "min_units": 1},
//!   "dataplane": {"placement": "skewed:8:0.7:r2",  // physical data plane
//!                 // layout resident|uniform:n|skewed:n:frac|single:r|fed:c:a,
//!                 // optional :rK suffix = K replica copies per shard,
//!                 // optional @shard=r1,r2 per-shard residency overrides
//!                 "mode": "joint",     // compute-follows-data | data-follows-compute | joint
//!                 "sample_kb": 256, "rebalance": true,
//!                 "replica_map": "shards.json"},  // whole-catalog replica-set
//!                 // pins from a JSON file {"<shard>": [region, ...], ...};
//!                 // inline @ pins in "placement" win per shard
//!   "spot": {"enabled": true,          // preemptible capacity market
//!            "discount": 0.35,         // mean spot price vs on-demand, (0, 1]
//!            "volatility": 0.25,       // per-segment price noise, [0, 1)
//!            "preempt_per_hour": 0.5,  // mean revocations/hour per spot pool
//!            "restore_stall_s": 30,    // checkpoint-restore stall per revocation
//!            "segment_s": 300, "seed": 0},  // price segment length; 0 = job seed
//!   "federated": {"clients": 100000,   // edge-cohort tier below the clouds
//!                 "cohorts": 40,       // aggregator pools per cloud (0 = flat)
//!                 "sample_frac": 0.1,  // clients sampled per round, (0, 1]
//!                 "dropout": 0.05},    // per-sampled-client dropout, [0, 1)
//!   "worker_cores": 3,
//!   "cohort_threshold": 64,            // aggregate >64-worker pools into cohort waves (0 = off)
//!   "link": {"bandwidth_mbps": 100, "latency_ms": 15,
//!             "fluct_sigma": 0.25, "drop_prob": 0.0},
//!   "regions": [                        // required, >= 1
//!     {"name": "Shanghai",  "device": "cascade", "units": 12, "data": 2048},
//!     {"name": "Chongqing", "device": "sky",     "units": 12, "data": 1024}
//!   ]
//! }
//! ```
//!
//! Every key is documented with its semantics and defaults in
//! docs/CONFIG.md; the `config_files_in_repo_parse` integration test
//! keeps the shipped `configs/*.json` set parsing.

use anyhow::{Context, Result};

use crate::cloud::devices::Device;
use crate::cloud::{CloudEnv, Region};
use crate::coordinator::fleet::{LeasePolicy, MultiJobParams};
use crate::coordinator::{JobSpec, SchedulingMode};
use crate::dataplane::{PlacementMode, PlacementSpec};
use crate::engine::TopologyKind;
use crate::net::LinkSpec;
use crate::sync::{Compression, Strategy, SyncConfig};
use crate::train::TrainConfig;
use crate::util::json::Json;

/// Parse a JSON config document into a [`JobSpec`].
pub fn parse_job(text: &str) -> Result<JobSpec> {
    let j = Json::parse(text).context("parsing job config")?;

    let model =
        j.get("model").as_str().ok_or_else(|| anyhow::anyhow!("config missing \"model\""))?;

    // regions -> CloudEnv
    let regions_json =
        j.get("regions").as_arr().ok_or_else(|| anyhow::anyhow!("config missing \"regions\""))?;
    anyhow::ensure!(!regions_json.is_empty(), "need at least one region");
    let mut regions = Vec::new();
    for (i, r) in regions_json.iter().enumerate() {
        let name = r.get("name").as_str().map(String::from).unwrap_or(format!("region{i}"));
        let dev_name = r.get("device").as_str().unwrap_or("cascade");
        let device = Device::from_name(dev_name)
            .ok_or_else(|| anyhow::anyhow!("unknown device {dev_name:?}"))?;
        let units = r.get("units").as_usize().unwrap_or(12) as u32;
        let data = r.get("data").as_usize().unwrap_or(1024);
        regions.push(Region::new(i, &name, vec![(device, units)], data));
    }
    let env = CloudEnv::new(regions);

    let mut train = TrainConfig::new(model);
    if let Some(e) = j.get("epochs").as_usize() {
        train.epochs = e;
    }
    if let Some(lr) = j.get("lr").as_f64() {
        train.lr = lr as f32;
    }
    if let Some(s) = j.get("seed").as_f64() {
        train.seed = s as u64;
    }
    if let Some(n) = j.get("n_train").as_usize() {
        train.n_train = n;
    }
    if let Some(n) = j.get("n_eval").as_usize() {
        train.n_eval = n;
    }
    if let Some(w) = j.get("worker_cores").as_usize() {
        train.worker_cores = w as u32;
    }
    if let Some(b) = j.get("base_step_s").as_f64() {
        train.base_step_s = b;
    }
    if let Some(e) = j.get("eval_every").as_usize() {
        train.eval_every = e;
    }
    if j.get("skip_eval").as_bool() == Some(true) {
        train.skip_eval = true;
    }
    let cohort = j.get("cohort_threshold");
    if !cohort.is_null() {
        train.cohort_threshold = cohort.as_usize().ok_or_else(|| {
            anyhow::anyhow!("\"cohort_threshold\" must be a non-negative integer (0 = off)")
        })?;
    }
    let lanes = j.get("wan_lanes");
    if !lanes.is_null() {
        train.wan_lanes = lanes
            .as_bool()
            .ok_or_else(|| anyhow::anyhow!("\"wan_lanes\" must be a boolean"))?;
    }
    let relays = j.get("relay_routes");
    if !relays.is_null() {
        train.relay_routes = relays
            .as_bool()
            .ok_or_else(|| anyhow::anyhow!("\"relay_routes\" must be a boolean"))?;
    }
    let auto_comp = j.get("auto_compression");
    if !auto_comp.is_null() {
        train.elastic.auto_compression = auto_comp
            .as_bool()
            .ok_or_else(|| anyhow::anyhow!("\"auto_compression\" must be a boolean"))?;
    }

    let strategy_name = j.get("strategy").as_str().unwrap_or("asgd");
    let strategy = Strategy::from_name(strategy_name).map_err(|e| anyhow::anyhow!(e))?;
    let freq = j.get("sync_freq").as_usize().unwrap_or(1) as u32;
    train.sync = SyncConfig::new(strategy, freq);
    let compression = j.get("compression");
    if !compression.is_null() {
        let c = compression.as_str().ok_or_else(|| {
            anyhow::anyhow!("\"compression\" must be a string (e.g. \"topk:0.25\")")
        })?;
        train.sync = train.sync.with_compression(
            Compression::from_name(c).map_err(|e| anyhow::anyhow!(e))?,
        );
    }
    let topology = j.get("topology");
    if !topology.is_null() {
        let t = topology
            .as_str()
            .ok_or_else(|| anyhow::anyhow!("\"topology\" must be a string (e.g. \"ring\")"))?;
        train.topology = TopologyKind::from_name(t).map_err(|e| anyhow::anyhow!(e))?;
    }

    let link = j.get("link");
    if !link.is_null() {
        train.link = LinkSpec {
            bandwidth_bps: link.get("bandwidth_mbps").as_f64().unwrap_or(100.0) * 1e6,
            latency_s: link.get("latency_ms").as_f64().unwrap_or(15.0) / 1e3,
            fluct_sigma: link.get("fluct_sigma").as_f64().unwrap_or(0.25),
            drop_prob: link.get("drop_prob").as_f64().unwrap_or(0.0),
            setup_s: link.get("setup_ms").as_f64().unwrap_or(90.0) / 1e3,
        };
    }

    let scheduling = match j.get("scheduling").as_str().unwrap_or("elastic") {
        "greedy" => SchedulingMode::Greedy,
        "elastic" => SchedulingMode::Elastic,
        other => anyhow::bail!("unknown scheduling mode {other:?}"),
    };

    let elastic = j.get("elastic");
    if !elastic.is_null() {
        anyhow::ensure!(
            elastic.as_obj().is_some(),
            "\"elastic\" must be an object (e.g. {{\"enabled\": true}})"
        );
        if let Some(e) = elastic.get("enabled").as_bool() {
            train.elastic.enabled = e;
        }
        if let Some(v) = elastic.get("interval_s").as_f64() {
            train.elastic.interval_s = v;
        }
        if let Some(v) = elastic.get("hysteresis").as_f64() {
            train.elastic.hysteresis = v;
        }
        if let Some(v) = elastic.get("bw_threshold").as_f64() {
            train.elastic.bw_threshold = v;
        }
        if let Some(v) = elastic.get("smoothing").as_f64() {
            train.elastic.smoothing = v;
        }
        train.elastic.validate().map_err(|e| anyhow::anyhow!(e))?;
    }

    let dp = j.get("dataplane");
    if !dp.is_null() {
        anyhow::ensure!(
            dp.as_obj().is_some(),
            "\"dataplane\" must be an object (e.g. {{\"placement\": \"skewed:8:0.7\"}})"
        );
        if let Some(p) = dp.get("placement").as_str() {
            train.dataplane.placement =
                Some(PlacementSpec::from_name(p).map_err(|e| anyhow::anyhow!(e))?);
        }
        if let Some(m) = dp.get("mode").as_str() {
            train.dataplane.mode =
                PlacementMode::from_name(m).map_err(|e| anyhow::anyhow!(e))?;
        }
        if let Some(kb) = dp.get("sample_kb").as_f64() {
            // 0 = derive bytes from the model's tensor geometry (the
            // documented default), matching the CLI's --sample-kb.
            anyhow::ensure!(kb >= 0.0, "dataplane sample_kb must be >= 0, got {kb}");
            train.dataplane.sample_bytes = (kb * 1024.0) as u64;
        }
        if let Some(r) = dp.get("rebalance").as_bool() {
            train.dataplane.rebalance = r;
        }
        if let Some(v) = dp.get("time_value_per_hour").as_f64() {
            anyhow::ensure!(v >= 0.0, "dataplane time_value_per_hour must be >= 0, got {v}");
            train.dataplane.time_value_per_hour = v;
        }
        anyhow::ensure!(
            train.dataplane.placement.is_some(),
            "\"dataplane\" block needs a \"placement\" spec"
        );
        let rm = dp.get("replica_map");
        if !rm.is_null() {
            let path = rm.as_str().ok_or_else(|| {
                anyhow::anyhow!("dataplane \"replica_map\" must be a file path string")
            })?;
            let map =
                crate::dataplane::load_replica_map(path).map_err(|e| anyhow::anyhow!(e))?;
            let spec = train.dataplane.placement.take().expect("ensured above");
            train.dataplane.placement = Some(spec.with_replica_map(map));
            train.dataplane.replica_map = Some(path.to_string());
        }
    }

    let fed = j.get("federated");
    if !fed.is_null() {
        anyhow::ensure!(
            fed.as_obj().is_some(),
            "\"federated\" must be an object (e.g. {{\"clients\": 100000, \"cohorts\": 40}})"
        );
        if let Some(c) = fed.get("clients").as_usize() {
            train.federated.clients = c;
        }
        if let Some(k) = fed.get("cohorts").as_usize() {
            train.federated.cohorts = k;
        }
        if let Some(f) = fed.get("sample_frac").as_f64() {
            train.federated.sample_frac = f;
        }
        if let Some(d) = fed.get("dropout").as_f64() {
            train.federated.dropout = d;
        }
        train.federated.validate().map_err(|e| anyhow::anyhow!(e))?;
        anyhow::ensure!(
            train.federated.clients > 0 && train.federated.cohorts > 0,
            "\"federated\" block needs \"clients\" > 0 and \"cohorts\" > 0 \
             (omit the block for a flat run)"
        );
    }

    let spot = j.get("spot");
    if !spot.is_null() {
        anyhow::ensure!(
            spot.as_obj().is_some(),
            "\"spot\" must be an object (e.g. {{\"enabled\": true}})"
        );
        if let Some(e) = spot.get("enabled").as_bool() {
            train.spot.enabled = e;
        }
        if let Some(v) = spot.get("discount").as_f64() {
            train.spot.discount = v;
        }
        if let Some(v) = spot.get("volatility").as_f64() {
            train.spot.volatility = v;
        }
        if let Some(v) = spot.get("preempt_per_hour").as_f64() {
            train.spot.preempt_per_hour = v;
        }
        if let Some(v) = spot.get("restore_stall_s").as_f64() {
            train.spot.restore_stall_s = v;
        }
        if let Some(v) = spot.get("segment_s").as_f64() {
            train.spot.segment_s = v;
        }
        if let Some(s) = spot.get("seed").as_f64() {
            train.spot.seed = s as u64;
        }
        train.spot.validate().map_err(|e| anyhow::anyhow!(e))?;
    }

    let mut multijob = None;
    let mj = j.get("multijob");
    if !mj.is_null() {
        anyhow::ensure!(
            mj.as_obj().is_some(),
            "\"multijob\" must be an object (e.g. {{\"jobs\": 4}})"
        );
        let mut params = MultiJobParams::default();
        if let Some(n) = mj.get("jobs").as_usize() {
            params.jobs = n;
        }
        if let Some(v) = mj.get("mean_interarrival_s").as_f64() {
            params.mean_interarrival_s = v;
        }
        if let Some(p) = mj.get("policy").as_str() {
            params.policy = match p {
                "all" => None,
                name => Some(LeasePolicy::from_name(name).map_err(|e| anyhow::anyhow!(e))?),
            };
        }
        if let Some(m) = mj.get("min_units").as_usize() {
            params.min_units = m as u32;
        }
        params.validate().map_err(|e| anyhow::anyhow!(e))?;
        multijob = Some(params);
    }

    Ok(JobSpec { env, train, scheduling, multijob })
}

/// Load a job config from a file path.
pub fn load_job(path: impl AsRef<std::path::Path>) -> Result<JobSpec> {
    let text = std::fs::read_to_string(path.as_ref())
        .with_context(|| format!("reading {}", path.as_ref().display()))?;
    parse_job(&text)
}

#[cfg(test)]
mod tests {
    use super::*;

    const FULL: &str = r#"{
        "model": "resnet", "epochs": 7, "lr": 0.02, "seed": 9,
        "n_train": 1000, "n_eval": 100, "strategy": "ama", "sync_freq": 8,
        "scheduling": "greedy", "worker_cores": 4,
        "link": {"bandwidth_mbps": 50, "latency_ms": 30, "fluct_sigma": 0.1},
        "regions": [
            {"name": "A", "device": "cascade", "units": 12, "data": 600},
            {"name": "B", "device": "v100", "units": 2, "data": 400}
        ]
    }"#;

    #[test]
    fn full_config_parses() {
        let spec = parse_job(FULL).unwrap();
        assert_eq!(spec.train.model, "resnet");
        assert_eq!(spec.train.epochs, 7);
        assert_eq!(spec.train.sync.freq, 8);
        assert_eq!(spec.train.sync.strategy, Strategy::Ama);
        assert_eq!(spec.scheduling, SchedulingMode::Greedy);
        assert_eq!(spec.env.regions.len(), 2);
        assert_eq!(spec.env.regions[1].max_units(Device::V100), 2);
        assert!((spec.train.link.bandwidth_bps - 50e6).abs() < 1.0);
        assert!((spec.train.link.latency_s - 0.03).abs() < 1e-9);
    }

    #[test]
    fn minimal_config_defaults() {
        let spec = parse_job(
            r#"{"model":"lenet","regions":[{"name":"X","device":"sky","units":6,"data":100}]}"#,
        )
        .unwrap();
        assert_eq!(spec.scheduling, SchedulingMode::Elastic);
        assert_eq!(spec.train.sync.strategy, Strategy::Asgd);
        assert_eq!(spec.train.sync.freq, 1);
    }

    #[test]
    fn topology_and_ma_alias_parse() {
        let spec = parse_job(
            r#"{"model":"lenet","strategy":"ma","topology":"hierarchical",
                "regions":[{"name":"X","device":"sky","units":6,"data":100},
                           {"name":"Y","device":"sky","units":6,"data":100},
                           {"name":"Z","device":"sky","units":6,"data":100}]}"#,
        )
        .unwrap();
        assert_eq!(spec.train.sync.strategy, Strategy::Ama, "\"ma\" aliases AMA");
        assert_eq!(spec.train.topology, TopologyKind::Hierarchical);
        assert!(parse_job(
            r#"{"model":"lenet","topology":"mesh","regions":[{"device":"sky","units":1,"data":1}]}"#
        )
        .is_err());
        // Wrong JSON type must error, not silently fall back to ring.
        assert!(parse_job(
            r#"{"model":"lenet","topology":2,"regions":[{"device":"sky","units":1,"data":1}]}"#
        )
        .is_err());
    }

    #[test]
    fn elastic_block_parses() {
        let spec = parse_job(
            r#"{"model":"lenet",
                "elastic":{"enabled":true,"interval_s":30,"hysteresis":0.1,
                           "bw_threshold":0.4,"smoothing":0.7},
                "regions":[{"name":"X","device":"sky","units":6,"data":100}]}"#,
        )
        .unwrap();
        assert!(spec.train.elastic.enabled);
        assert!((spec.train.elastic.interval_s - 30.0).abs() < 1e-12);
        assert!((spec.train.elastic.hysteresis - 0.1).abs() < 1e-12);
        assert!((spec.train.elastic.bw_threshold - 0.4).abs() < 1e-12);
        assert!((spec.train.elastic.smoothing - 0.7).abs() < 1e-12);
        // Default: the control loop is off.
        let off = parse_job(
            r#"{"model":"lenet","regions":[{"name":"X","device":"sky","units":6,"data":100}]}"#,
        )
        .unwrap();
        assert!(!off.train.elastic.enabled);
        // Wrong JSON type errors rather than being silently ignored.
        assert!(parse_job(
            r#"{"model":"lenet","elastic":true,"regions":[{"device":"sky","units":1,"data":1}]}"#
        )
        .is_err());
        // smoothing=0 would make an enabled loop silently inert: reject.
        assert!(parse_job(
            r#"{"model":"lenet","elastic":{"enabled":true,"smoothing":0},
                "regions":[{"device":"sky","units":1,"data":1}]}"#
        )
        .is_err());
        assert!(parse_job(
            r#"{"model":"lenet","elastic":{"interval_s":-5},
                "regions":[{"device":"sky","units":1,"data":1}]}"#
        )
        .is_err());
    }

    #[test]
    fn cohort_threshold_parses() {
        let region = r#""regions":[{"name":"X","device":"sky","units":6,"data":100}]"#;
        let spec =
            parse_job(&format!(r#"{{"model":"lenet","cohort_threshold":64,{region}}}"#)).unwrap();
        assert_eq!(spec.train.cohort_threshold, 64);
        // Default: off — the exact per-worker simulation path.
        let off = parse_job(&format!(r#"{{"model":"lenet",{region}}}"#)).unwrap();
        assert_eq!(off.train.cohort_threshold, 0);
        // Wrong JSON type errors rather than being silently ignored.
        assert!(
            parse_job(&format!(r#"{{"model":"lenet","cohort_threshold":"big",{region}}}"#))
                .is_err()
        );
    }

    #[test]
    fn wan_lane_keys_parse() {
        let region = r#""regions":[{"name":"X","device":"sky","units":6,"data":100}]"#;
        let spec = parse_job(&format!(
            r#"{{"model":"lenet","wan_lanes":true,"relay_routes":true,
                "auto_compression":true,{region}}}"#
        ))
        .unwrap();
        assert!(spec.train.wan_lanes);
        assert!(spec.train.relay_routes);
        assert!(spec.train.elastic.auto_compression);
        // Defaults: all off — the seed's single-FIFO fabric and static
        // codec.
        let off = parse_job(&format!(r#"{{"model":"lenet",{region}}}"#)).unwrap();
        assert!(!off.train.wan_lanes);
        assert!(!off.train.relay_routes);
        assert!(!off.train.elastic.auto_compression);
        // Wrong JSON types error rather than being silently ignored.
        for bad in [
            r#""wan_lanes":"yes""#,
            r#""relay_routes":1"#,
            r#""auto_compression":"on""#,
        ] {
            let doc = format!(r#"{{"model":"lenet",{bad},{region}}}"#);
            assert!(parse_job(&doc).is_err(), "must reject: {doc}");
        }
    }

    #[test]
    fn compression_key_parses() {
        let region = r#""regions":[{"name":"X","device":"sky","units":6,"data":100}]"#;
        let spec = parse_job(&format!(
            r#"{{"model":"lenet","strategy":"asgd-ga","compression":"topk:0.25",{region}}}"#
        ))
        .unwrap();
        assert_eq!(spec.train.sync.compression, Compression::TopK { ratio: 0.25 });
        let q8 = parse_job(&format!(r#"{{"model":"lenet","compression":"q8",{region}}}"#)).unwrap();
        assert_eq!(q8.train.sync.compression, Compression::Q8);
        let none =
            parse_job(&format!(r#"{{"model":"lenet","compression":"none",{region}}}"#)).unwrap();
        assert_eq!(none.train.sync.compression, Compression::None);
        // Unknown codec / bad ratio / wrong JSON type all error.
        assert!(
            parse_job(&format!(r#"{{"model":"lenet","compression":"gzip",{region}}}"#)).is_err()
        );
        assert!(
            parse_job(&format!(r#"{{"model":"lenet","compression":"topk:1.5",{region}}}"#)).is_err()
        );
        assert!(parse_job(&format!(r#"{{"model":"lenet","compression":8,{region}}}"#)).is_err());
    }

    #[test]
    fn dataplane_block_parses() {
        let region = r#""regions":[{"name":"X","device":"sky","units":6,"data":100},
                                   {"name":"Y","device":"sky","units":6,"data":100}]"#;
        let spec = parse_job(&format!(
            r#"{{"model":"synthetic",
                "dataplane":{{"placement":"skewed:8:0.7","mode":"joint",
                              "sample_kb":256,"rebalance":false,
                              "time_value_per_hour":1.5}},{region}}}"#
        ))
        .unwrap();
        let dp = &spec.train.dataplane;
        assert_eq!(
            dp.placement,
            Some(PlacementSpec::new(crate::dataplane::Layout::Skewed { shards: 8, frac: 0.7 }))
        );
        assert_eq!(dp.mode, PlacementMode::Joint);
        // The :rK suffix carries the replica factor through the config.
        let replicated = parse_job(&format!(
            r#"{{"model":"synthetic",
                "dataplane":{{"placement":"skewed:8:0.7:r2"}},{region}}}"#
        ))
        .unwrap();
        let rp = replicated.train.dataplane.placement.unwrap();
        assert_eq!(rp.replication, 2);
        assert_eq!(rp.name(), "skewed:8:0.7:r2");
        assert_eq!(dp.sample_bytes, 256 * 1024);
        assert!(!dp.rebalance);
        assert!((dp.time_value_per_hour - 1.5).abs() < 1e-12);
        // Absent block: the data plane is off (seed behavior).
        let off = parse_job(&format!(r#"{{"model":"synthetic",{region}}}"#)).unwrap();
        assert!(!off.train.dataplane.enabled());
        // sample_kb 0 is the documented "derive from model geometry"
        // default (same as the CLI's --sample-kb 0), not an error.
        let derive = parse_job(&format!(
            r#"{{"model":"synthetic",
                "dataplane":{{"placement":"uniform:4","sample_kb":0}},{region}}}"#
        ))
        .unwrap();
        assert_eq!(derive.train.dataplane.sample_bytes, 0);
        // Errors: wrong type, missing placement, bad spec/mode/knobs.
        for bad in [
            r#""dataplane":"skewed""#,
            r#""dataplane":{"mode":"joint"}"#,
            r#""dataplane":{"placement":"striped:4"}"#,
            r#""dataplane":{"placement":"uniform:4:r0"}"#,
            r#""dataplane":{"placement":"uniform:4","mode":"teleport"}"#,
            r#""dataplane":{"placement":"uniform:4","sample_kb":-1}"#,
            r#""dataplane":{"placement":"uniform:4","time_value_per_hour":-1}"#,
        ] {
            let doc = format!(r#"{{"model":"synthetic",{bad},{region}}}"#);
            assert!(parse_job(&doc).is_err(), "must reject: {doc}");
        }
    }

    #[test]
    fn spot_block_parses() {
        let region = r#""regions":[{"name":"X","device":"sky","units":6,"data":100}]"#;
        let spec = parse_job(&format!(
            r#"{{"model":"synthetic",
                "spot":{{"enabled":true,"discount":0.3,"volatility":0.1,
                         "preempt_per_hour":2,"restore_stall_s":45,
                         "segment_s":120,"seed":7}},{region}}}"#
        ))
        .unwrap();
        let sp = &spec.train.spot;
        assert!(sp.enabled);
        assert!((sp.discount - 0.3).abs() < 1e-12);
        assert!((sp.volatility - 0.1).abs() < 1e-12);
        assert!((sp.preempt_per_hour - 2.0).abs() < 1e-12);
        assert!((sp.restore_stall_s - 45.0).abs() < 1e-12);
        assert!((sp.segment_s - 120.0).abs() < 1e-12);
        assert_eq!(sp.seed, 7);
        // Absent block: the market is off (the byte-identical seed path).
        let off = parse_job(&format!(r#"{{"model":"synthetic",{region}}}"#)).unwrap();
        assert!(!off.train.spot.enabled);
        // Errors: wrong type, out-of-range knobs.
        for bad in [
            r#""spot":true"#,
            r#""spot":{"enabled":true,"discount":0}"#,
            r#""spot":{"enabled":true,"discount":1.5}"#,
            r#""spot":{"enabled":true,"volatility":1}"#,
            r#""spot":{"enabled":true,"preempt_per_hour":-1}"#,
            r#""spot":{"enabled":true,"restore_stall_s":-5}"#,
            r#""spot":{"enabled":true,"segment_s":0}"#,
        ] {
            let doc = format!(r#"{{"model":"synthetic",{bad},{region}}}"#);
            assert!(parse_job(&doc).is_err(), "must reject: {doc}");
        }
    }

    #[test]
    fn dataplane_replica_map_file_parses() {
        let region = r#""regions":[{"name":"X","device":"sky","units":6,"data":100},
                                   {"name":"Y","device":"sky","units":6,"data":100}]"#;
        let path = std::env::temp_dir().join("cloudless_cfg_replica_map.json");
        std::fs::write(&path, r#"{"0": [1], "2": [0, 1]}"#).unwrap();
        let doc = format!(
            r#"{{"model":"synthetic",
                "dataplane":{{"placement":"uniform:4@2=1","replica_map":{path:?}}},{region}}}"#,
            path = path.display().to_string()
        );
        let spec = parse_job(&doc).unwrap();
        let placement = spec.train.dataplane.placement.unwrap();
        // Map pins fold in; the inline @2 pin wins over the map's entry.
        assert_eq!(placement.overrides, vec![(0, vec![1]), (2, vec![1])]);
        assert_eq!(spec.train.dataplane.replica_map.as_deref(), Some(path.to_str().unwrap()));
        // A missing file or wrong JSON type is a config error.
        assert!(parse_job(&format!(
            r#"{{"model":"synthetic",
                "dataplane":{{"placement":"uniform:4",
                              "replica_map":"/nonexistent/map.json"}},{region}}}"#
        ))
        .is_err());
        assert!(parse_job(&format!(
            r#"{{"model":"synthetic",
                "dataplane":{{"placement":"uniform:4","replica_map":7}},{region}}}"#
        ))
        .is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn federated_block_parses() {
        let region = r#""regions":[{"name":"X","device":"sky","units":6,"data":100},
                                   {"name":"Y","device":"sky","units":6,"data":100}]"#;
        let spec = parse_job(&format!(
            r#"{{"model":"synthetic",
                "federated":{{"clients":100000,"cohorts":40,
                              "sample_frac":0.1,"dropout":0.05}},{region}}}"#
        ))
        .unwrap();
        let fed = &spec.train.federated;
        assert!(fed.active());
        assert_eq!(fed.clients, 100_000);
        assert_eq!(fed.cohorts, 40);
        assert!((fed.sample_frac - 0.1).abs() < 1e-12);
        assert!((fed.dropout - 0.05).abs() < 1e-12);
        // Sampling knobs default to full participation, no churn.
        let minimal = parse_job(&format!(
            r#"{{"model":"synthetic","federated":{{"clients":64,"cohorts":4}},{region}}}"#
        ))
        .unwrap();
        assert!((minimal.train.federated.sample_frac - 1.0).abs() < 1e-12);
        assert!((minimal.train.federated.dropout - 0.0).abs() < 1e-12);
        // Absent block: the edge tier is off and the engine stays flat.
        let flat = parse_job(&format!(r#"{{"model":"synthetic",{region}}}"#)).unwrap();
        assert!(!flat.train.federated.active());
        // The fed: layout rides through the dataplane block alongside it.
        let skewed = parse_job(&format!(
            r#"{{"model":"synthetic",
                "federated":{{"clients":1000,"cohorts":8}},
                "dataplane":{{"placement":"fed:1000:0.3"}},{region}}}"#
        ))
        .unwrap();
        assert_eq!(
            skewed.train.dataplane.placement.as_ref().unwrap().layout,
            crate::dataplane::Layout::Federated { clients: 1000, alpha: 0.3 }
        );
        // Errors: wrong type, zero populations, out-of-range knobs.
        for bad in [
            r#""federated":true"#,
            r#""federated":{"clients":0,"cohorts":4}"#,
            r#""federated":{"clients":100,"cohorts":0}"#,
            r#""federated":{"clients":100,"cohorts":4,"sample_frac":0}"#,
            r#""federated":{"clients":100,"cohorts":4,"sample_frac":1.5}"#,
            r#""federated":{"clients":100,"cohorts":4,"dropout":1}"#,
        ] {
            let doc = format!(r#"{{"model":"synthetic",{bad},{region}}}"#);
            assert!(parse_job(&doc).is_err(), "must reject: {doc}");
        }
    }

    #[test]
    fn multijob_block_parses() {
        use crate::coordinator::fleet::LeasePolicy;
        let region = r#""regions":[{"name":"X","device":"sky","units":12,"data":100}]"#;
        let spec = parse_job(&format!(
            r#"{{"model":"synthetic",
                "multijob":{{"jobs":6,"mean_interarrival_s":40,"policy":"fair-share",
                             "min_units":2}},{region}}}"#
        ))
        .unwrap();
        let mj = spec.multijob.expect("multijob block parsed");
        assert_eq!(mj.jobs, 6);
        assert!((mj.mean_interarrival_s - 40.0).abs() < 1e-12);
        assert_eq!(mj.policy, Some(LeasePolicy::FairShare));
        assert_eq!(mj.min_units, 2);
        // "all" means compare every policy; absent block means None.
        let all = parse_job(&format!(
            r#"{{"model":"synthetic","multijob":{{"policy":"all"}},{region}}}"#
        ))
        .unwrap();
        assert_eq!(all.multijob.unwrap().policy, None);
        let plain = parse_job(&format!(r#"{{"model":"synthetic",{region}}}"#)).unwrap();
        assert!(plain.multijob.is_none());
        // Invalid knobs error instead of silently defaulting.
        assert!(parse_job(&format!(
            r#"{{"model":"synthetic","multijob":{{"jobs":0}},{region}}}"#
        ))
        .is_err());
        assert!(parse_job(&format!(
            r#"{{"model":"synthetic","multijob":{{"policy":"lottery"}},{region}}}"#
        ))
        .is_err());
        assert!(
            parse_job(&format!(r#"{{"model":"synthetic","multijob":true,{region}}}"#)).is_err()
        );
    }

    #[test]
    fn errors_are_descriptive() {
        assert!(parse_job(r#"{"regions":[]}"#).is_err());
        assert!(parse_job(r#"{"model":"lenet","regions":[]}"#).is_err());
        assert!(parse_job(
            r#"{"model":"lenet","regions":[{"device":"tpu9000","units":1,"data":1}]}"#
        )
        .is_err());
        assert!(parse_job(
            r#"{"model":"lenet","strategy":"nope","regions":[{"device":"sky","units":1,"data":1}]}"#
        )
        .is_err());
    }
}

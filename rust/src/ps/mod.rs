//! Parameter-server state — the stateful PS function at the heart of each
//! cloud-level training partition.
//!
//! Workers pull the latest local model, compute SGD gradients (real PJRT
//! compute), and push them back; the PS applies them immediately
//! (asynchronous local update, as in the paper's ElasticDL-derived
//! training plane) while maintaining the *accumulated gradient* that the
//! gradient-based WAN strategies (ASGD / ASGD-GA) ship to peer clouds.
//!
//! Versioning counts every parameter mutation so gradient staleness
//! (worker pulled at version v, pushed at version v') is measurable — the
//! paper argues elastic scheduling improves accuracy precisely by
//! reducing staleness.

use crate::runtime::vecops;

/// The mutable state of one cloud's parameter server.
#[derive(Debug, Clone)]
pub struct PsState {
    /// Current model parameters (flat f32, the runtime convention).
    pub params: Vec<f32>,
    /// Gradient accumulated since the last WAN sync (ASGD/ASGD-GA payload).
    pub accum: Vec<f32>,
    /// Number of gradients merged into `accum` since the last sync.
    pub accum_steps: u32,
    /// Local SGD updates applied since the last WAN sync.
    pub updates_since_sync: u32,
    /// Total local updates ever applied.
    pub total_updates: u64,
    /// Parameter version: bumped by every mutation (local or remote).
    pub version: u64,
    /// Learning rate used for local and remote-gradient application.
    pub lr: f32,
    /// Planned (synchronous) weight of model-averaging payloads applied
    /// since this PS last snapshotted its own model — the communicator's
    /// input to `engine::topology::sequential_weight` compensation.
    pub applied_weight_since_snapshot: f32,
    // --- statistics ---
    pub sends: u64,
    pub recvs: u64,
    /// Sum + count of observed staleness (version delta between pull and
    /// push) for averaging.
    pub staleness_sum: u64,
    pub staleness_n: u64,
}

impl PsState {
    pub fn new(init_params: Vec<f32>, lr: f32) -> PsState {
        let n = init_params.len();
        PsState {
            params: init_params,
            accum: vec![0.0; n],
            accum_steps: 0,
            updates_since_sync: 0,
            total_updates: 0,
            version: 0,
            lr,
            applied_weight_since_snapshot: 0.0,
            sends: 0,
            recvs: 0,
            staleness_sum: 0,
            staleness_n: 0,
        }
    }

    /// Worker pull: snapshot of the current model + its version.
    pub fn pull(&self) -> (Vec<f32>, u64) {
        (self.params.clone(), self.version)
    }

    /// Worker push: apply the gradient locally (async SGD) and merge it
    /// into the accumulator. `pulled_version` is what the worker trained
    /// against (staleness accounting).
    pub fn push_gradient(&mut self, grad: &[f32], pulled_version: u64) {
        vecops::sgd_apply_inplace(&mut self.params, grad, self.lr);
        vecops::accumulate_inplace(&mut self.accum, grad);
        self.accum_steps += 1;
        self.updates_since_sync += 1;
        self.total_updates += 1;
        self.staleness_sum += self.version - pulled_version;
        self.staleness_n += 1;
        self.version += 1;
    }

    /// Worker-cohort push: what `n` sequential [`PsState::push_gradient`]
    /// calls of the same gradient/pulled-version would do, in one O(|g|)
    /// application (SGD is linear, so `n` applications of `g` equal one
    /// application of `n·g`; the staleness sum models the `n` sequential
    /// version bumps exactly). The engine's cohort waves (see
    /// `engine::partition::cohort_size`) push one representative gradient
    /// per wave weighted by the wave's iteration count. `n == 1` is
    /// byte-identical to `push_gradient`.
    pub fn push_gradient_weighted(&mut self, grad: &[f32], pulled_version: u64, n: u32) {
        if n == 0 {
            return;
        }
        if n == 1 {
            return self.push_gradient(grad, pulled_version);
        }
        let scaled: Vec<f32> = grad.iter().map(|g| g * n as f32).collect();
        vecops::sgd_apply_inplace(&mut self.params, &scaled, self.lr);
        vecops::accumulate_inplace(&mut self.accum, &scaled);
        self.accum_steps += n;
        self.updates_since_sync += n;
        self.total_updates += n as u64;
        // Push i of the modeled sequence sees i extra version bumps.
        let n64 = n as u64;
        self.staleness_sum += (self.version - pulled_version) * n64 + n64 * (n64 - 1) / 2;
        self.staleness_n += n64;
        self.version += n64;
    }

    /// Take the accumulated gradient for a WAN send, resetting it.
    pub fn take_accumulated(&mut self) -> (Vec<f32>, u32) {
        let steps = self.accum_steps;
        let grad = std::mem::replace(&mut self.accum, vec![0.0; self.params.len()]);
        self.accum_steps = 0;
        self.updates_since_sync = 0;
        self.sends += 1;
        (grad, steps)
    }

    /// Snapshot parameters for a model-averaging send. Resets the
    /// sequential-compensation window: payloads applied after this
    /// snapshot mix against the freshly-shipped model.
    pub fn snapshot_params(&mut self) -> Vec<f32> {
        self.updates_since_sync = 0;
        self.sends += 1;
        self.applied_weight_since_snapshot = 0.0;
        self.params.clone()
    }

    /// Record that a model-averaging payload of planned weight `w` was
    /// applied (sequential-compensation accounting).
    pub fn note_applied_weight(&mut self, w: f32) {
        self.applied_weight_since_snapshot += w;
    }

    /// Apply a remote accumulated gradient (receiver side of ASGD/ASGD-GA).
    pub fn apply_remote_gradient(&mut self, grad: &[f32]) {
        vecops::sgd_apply_inplace(&mut self.params, grad, self.lr);
        self.version += 1;
        self.recvs += 1;
    }

    /// Average with remote parameters (receiver side of AMA/SMA);
    /// `w` is the local weight.
    pub fn average_with(&mut self, remote: &[f32], w: f32) {
        vecops::average_inplace(&mut self.params, remote, w);
        self.version += 1;
        self.recvs += 1;
    }

    /// Mean observed gradient staleness.
    pub fn mean_staleness(&self) -> f64 {
        if self.staleness_n == 0 {
            0.0
        } else {
            self.staleness_sum as f64 / self.staleness_n as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ps() -> PsState {
        PsState::new(vec![1.0, 2.0, 3.0], 0.1)
    }

    #[test]
    fn push_applies_sgd_and_accumulates() {
        let mut s = ps();
        s.push_gradient(&[1.0, 0.0, -1.0], 0);
        assert_eq!(s.params, vec![0.9, 2.0, 3.1]);
        assert_eq!(s.accum, vec![1.0, 0.0, -1.0]);
        s.push_gradient(&[1.0, 1.0, 1.0], 1);
        assert_eq!(s.accum, vec![2.0, 1.0, 0.0]);
        assert_eq!(s.accum_steps, 2);
        assert_eq!(s.version, 2);
        assert_eq!(s.total_updates, 2);
    }

    #[test]
    fn take_accumulated_resets() {
        let mut s = ps();
        s.push_gradient(&[1.0, 1.0, 1.0], 0);
        s.push_gradient(&[0.5, 0.5, 0.5], 1);
        let (g, steps) = s.take_accumulated();
        assert_eq!(g, vec![1.5, 1.5, 1.5]);
        assert_eq!(steps, 2);
        assert_eq!(s.accum, vec![0.0, 0.0, 0.0]);
        assert_eq!(s.accum_steps, 0);
        assert_eq!(s.updates_since_sync, 0);
        assert_eq!(s.sends, 1);
    }

    #[test]
    fn staleness_tracking() {
        let mut s = ps();
        s.push_gradient(&[0.0; 3], 0); // version 0 -> staleness 0
        s.push_gradient(&[0.0; 3], 0); // pulled at 0, version now 1 -> staleness 1
        s.push_gradient(&[0.0; 3], 1); // staleness 1
        assert!((s.mean_staleness() - 2.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn weighted_push_matches_sequential_pushes() {
        // Powers of two keep f32 arithmetic exact, so the equality is
        // bitwise, not approximate.
        let grad = [0.5f32, -0.25, 1.0];
        let mut seq = PsState::new(vec![1.0, 2.0, 3.0], 0.125);
        let mut agg = seq.clone();
        seq.push_gradient(&grad, 0);
        seq.push_gradient(&grad, 0);
        seq.push_gradient(&grad, 0);
        seq.push_gradient(&grad, 0);
        agg.push_gradient_weighted(&grad, 0, 4);
        assert_eq!(seq.params, agg.params);
        assert_eq!(seq.accum, agg.accum);
        assert_eq!(seq.accum_steps, agg.accum_steps);
        assert_eq!(seq.updates_since_sync, agg.updates_since_sync);
        assert_eq!(seq.total_updates, agg.total_updates);
        assert_eq!(seq.version, agg.version);
        assert_eq!(seq.staleness_sum, agg.staleness_sum);
        assert_eq!(seq.staleness_n, agg.staleness_n);

        // n == 1 delegates; n == 0 is a no-op.
        let mut one = PsState::new(vec![1.0, 2.0, 3.0], 0.125);
        let mut direct = one.clone();
        one.push_gradient_weighted(&grad, 0, 1);
        direct.push_gradient(&grad, 0);
        assert_eq!(one.params, direct.params);
        assert_eq!(one.version, direct.version);
        let before = one.version;
        one.push_gradient_weighted(&grad, 0, 0);
        assert_eq!(one.version, before);
    }

    #[test]
    fn remote_gradient_application() {
        let mut s = ps();
        s.apply_remote_gradient(&[1.0, -1.0, 0.0]);
        assert_eq!(s.params, vec![0.9, 2.1, 3.0]);
        assert_eq!(s.recvs, 1);
        assert_eq!(s.version, 1);
    }

    #[test]
    fn model_average_with_remote() {
        let mut s = ps();
        s.average_with(&[3.0, 4.0, 5.0], 0.5);
        assert_eq!(s.params, vec![2.0, 3.0, 4.0]);
    }

    #[test]
    fn pull_snapshots_do_not_alias() {
        let mut s = ps();
        let (snap, v) = s.pull();
        s.push_gradient(&[1.0, 1.0, 1.0], v);
        assert_eq!(snap, vec![1.0, 2.0, 3.0], "snapshot must be stable");
    }
}

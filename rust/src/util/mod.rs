//! Foundation utilities built in-tree (this environment vendors no crates
//! beyond `xla`/`anyhow`): deterministic PRNG, JSON, CLI args, f32 binary
//! I/O, and simple stat helpers.

pub mod args;
pub mod json;
pub mod rng;

use std::io::{Read, Write};
use std::path::Path;

/// Read a little-endian f32 binary file (the `{model}_init.bin` format).
pub fn read_f32_file(path: &Path) -> anyhow::Result<Vec<f32>> {
    let mut bytes = Vec::new();
    std::fs::File::open(path)
        .map_err(|e| anyhow::anyhow!("open {}: {e}", path.display()))?
        .read_to_end(&mut bytes)?;
    anyhow::ensure!(bytes.len() % 4 == 0, "{}: length not a multiple of 4", path.display());
    Ok(bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

/// Write a little-endian f32 binary file.
pub fn write_f32_file(path: &Path, data: &[f32]) -> anyhow::Result<()> {
    let mut f = std::fs::File::create(path)?;
    let mut buf = Vec::with_capacity(data.len() * 4);
    for x in data {
        buf.extend_from_slice(&x.to_le_bytes());
    }
    f.write_all(&buf)?;
    Ok(())
}

/// Mean of a slice (0.0 for empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Population standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Percentile (nearest-rank) of an unsorted slice.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = ((p / 100.0) * (v.len() as f64 - 1.0)).round() as usize;
    v[rank.min(v.len() - 1)]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f32_file_roundtrip() {
        let dir = std::env::temp_dir().join(format!("cloudless_test_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("x.bin");
        let data = vec![1.0f32, -2.5, 3.25, f32::MIN_POSITIVE];
        write_f32_file(&path, &data).unwrap();
        assert_eq!(read_f32_file(&path).unwrap(), data);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn stats() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((mean(&xs) - 2.5).abs() < 1e-12);
        assert!((stddev(&xs) - 1.118033988749895).abs() < 1e-9);
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
    }
}

//! Tiny CLI argument parser (no clap offline): subcommand + `--key value` /
//! `--flag` options with typed accessors and a generated usage string.

use std::collections::BTreeMap;

#[derive(Debug, Clone)]
pub struct Args {
    pub subcommand: Option<String>,
    pub positional: Vec<String>,
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
}

impl Args {
    /// Parse `argv[1..]`. The first bare word becomes the subcommand; later
    /// bare words are positional. `--key value` and `--key=value` both work;
    /// a `--key` followed by another option (or end) is a boolean flag.
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Args {
        let items: Vec<String> = argv.into_iter().collect();
        let mut a = Args {
            subcommand: None,
            positional: Vec::new(),
            opts: BTreeMap::new(),
            flags: Vec::new(),
        };
        let mut i = 0;
        while i < items.len() {
            let it = &items[i];
            if let Some(name) = it.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    a.opts.insert(k.to_string(), v.to_string());
                } else if i + 1 < items.len() && !items[i + 1].starts_with("--") {
                    a.opts.insert(name.to_string(), items[i + 1].clone());
                    i += 1;
                } else {
                    a.flags.push(name.to_string());
                }
            } else if a.subcommand.is_none() && a.positional.is_empty() {
                a.subcommand = Some(it.clone());
            } else {
                a.positional.push(it.clone());
            }
            i += 1;
        }
        a
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.opts.get(key).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key) || self.get(key) == Some("true")
    }

    pub fn usize(&self, key: &str, default: usize) -> usize {
        self.get(key).map(|v| v.parse().unwrap_or_else(|_| panic!("--{key} expects an integer, got {v:?}"))).unwrap_or(default)
    }

    pub fn u64(&self, key: &str, default: u64) -> u64 {
        self.get(key).map(|v| v.parse().unwrap_or_else(|_| panic!("--{key} expects an integer, got {v:?}"))).unwrap_or(default)
    }

    pub fn f64(&self, key: &str, default: f64) -> f64 {
        self.get(key).map(|v| v.parse().unwrap_or_else(|_| panic!("--{key} expects a number, got {v:?}"))).unwrap_or(default)
    }

    /// Run `--key` (or `default` when absent) through a domain parser,
    /// surfacing the parser's own message as a `--key: ...` CLI error —
    /// so enum options like `--strategy` fail with the list of valid
    /// names instead of a bare "unknown" or a silent `None`.
    pub fn parsed<T, E: std::fmt::Display>(
        &self,
        key: &str,
        default: &str,
        parse: impl FnOnce(&str) -> Result<T, E>,
    ) -> anyhow::Result<T> {
        parse(self.get_or(key, default)).map_err(|e| anyhow::anyhow!("--{key}: {e}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn subcommand_and_options() {
        let a = parse("train extra --model lenet --epochs 10 --quick");
        assert_eq!(a.subcommand.as_deref(), Some("train"));
        assert_eq!(a.get("model"), Some("lenet"));
        assert_eq!(a.usize("epochs", 1), 10);
        assert!(a.flag("quick"));
        assert_eq!(a.positional, vec!["extra".to_string()]);
    }

    #[test]
    fn equals_syntax() {
        let a = parse("exp --id=fig8 --scale=0.5");
        assert_eq!(a.get("id"), Some("fig8"));
        assert_eq!(a.f64("scale", 1.0), 0.5);
    }

    #[test]
    fn defaults() {
        let a = parse("plan");
        assert_eq!(a.usize("epochs", 7), 7);
        assert!(!a.flag("quick"));
        assert_eq!(a.get_or("model", "lenet"), "lenet");
    }

    #[test]
    fn trailing_flag() {
        let a = parse("train --verbose");
        assert!(a.flag("verbose"));
    }

    #[test]
    fn parsed_surfaces_domain_errors() {
        let ok = |s: &str| -> Result<usize, String> { Ok(s.len()) };
        let bad = |s: &str| -> Result<usize, String> { Err(format!("{s:?} is not valid")) };
        let a = parse("train --mode fast");
        assert_eq!(a.parsed("mode", "slow", ok).unwrap(), 4);
        assert_eq!(a.parsed("missing", "xx", ok).unwrap(), 2, "default goes through parser");
        let err = a.parsed("mode", "slow", bad).unwrap_err().to_string();
        assert!(err.contains("--mode") && err.contains("\"fast\" is not valid"), "{err}");
    }
}

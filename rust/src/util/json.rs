//! Minimal JSON parser + writer (no serde in this offline environment).
//!
//! Covers the full JSON grammar (objects, arrays, strings with escapes,
//! numbers, bools, null). Used for: artifact `*_meta.json`, config files
//! under `configs/`, and experiment result dumps. Numbers parse to f64
//! (ints exposed via accessors); this is plenty for config/metadata use.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub msg: String,
    pub pos: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    // ---------- accessors ----------
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }
    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|x| x as i64)
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|x| if x >= 0.0 { Some(x as usize) } else { None })
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }
    /// Object field lookup; `Json::Null` for missing keys / non-objects.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Obj(m) => m.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }
    pub fn is_null(&self) -> bool {
        matches!(self, Json::Null)
    }

    // ---------- builders ----------
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }
    pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
        Json::Arr(items.into_iter().collect())
    }
    pub fn num(x: f64) -> Json {
        Json::Num(x)
    }
    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    // ---------- parse ----------
    pub fn parse(input: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: input.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // ---------- write ----------
    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, true);
        out
    }

    pub fn to_string_compact(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, false);
        out
    }

    fn write(&self, out: &mut String, indent: usize, pretty: bool) {
        let pad = |out: &mut String, n: usize| {
            if pretty {
                out.push('\n');
                for _ in 0..n {
                    out.push_str("  ");
                }
            }
        };
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 9.0e15 {
                    out.push_str(&format!("{}", *x as i64));
                } else {
                    out.push_str(&format!("{x}"));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (idx, item) in v.iter().enumerate() {
                    if idx > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    item.write(out, indent + 1, pretty);
                }
                if !v.is_empty() {
                    pad(out, indent);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (idx, (k, val)) in m.iter().enumerate() {
                    if idx > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if pretty {
                        out.push(' ');
                    }
                    val.write(out, indent + 1, pretty);
                }
                if !m.is_empty() {
                    pad(out, indent);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), pos: self.i }
    }

    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            self.skip_ws();
            let val = self.value()?;
            m.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.skip_ws();
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'n') => s.push('\n'),
                        Some(b'r') => s.push('\r'),
                        Some(b't') => s.push('\t'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            // Surrogate pairs: parse the low half if present.
                            let c = if (0xD800..0xDC00).contains(&cp) {
                                self.i += 5;
                                if self.b.get(self.i) != Some(&b'\\')
                                    || self.b.get(self.i + 1) != Some(&b'u')
                                {
                                    return Err(self.err("missing low surrogate"));
                                }
                                let hex2 = std::str::from_utf8(&self.b[self.i + 2..self.i + 6])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                                let lo = u32::from_str_radix(hex2, 16)
                                    .map_err(|_| self.err("bad \\u escape"))?;
                                self.i += 1; // compensate the standard advance below
                                char::from_u32(0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00))
                            } else {
                                char::from_u32(cp)
                            };
                            s.push(c.ok_or_else(|| self.err("invalid codepoint"))?);
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self.peek().map_or(false, |c| c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while self.peek().map_or(false, |c| c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.i += 1;
            }
            while self.peek().map_or(false, |c| c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-1.5e3").unwrap(), Json::Num(-1500.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x\ny"}"#).unwrap();
        assert_eq!(j.get("a").as_arr().unwrap().len(), 3);
        assert_eq!(j.get("c").as_str().unwrap(), "x\ny");
        assert!(j.get("a").as_arr().unwrap()[2].get("b").is_null());
    }

    #[test]
    fn parse_unicode_escapes() {
        let j = Json::parse(r#""é😀""#).unwrap();
        assert_eq!(j.as_str().unwrap(), "é😀");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"name":"lenet","param_count":61706,"x_shape":[28,28,1],"nested":{"f":1.25,"t":true,"n":null},"s":"a\"b\\c"}"#;
        let j = Json::parse(src).unwrap();
        let again = Json::parse(&j.to_string_compact()).unwrap();
        assert_eq!(j, again);
        let pretty = Json::parse(&j.to_string_pretty()).unwrap();
        assert_eq!(j, pretty);
    }

    #[test]
    fn real_meta_file_parses() {
        let meta = std::fs::read_to_string(
            concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts/lenet_meta.json"),
        );
        if let Ok(meta) = meta {
            let j = Json::parse(&meta).unwrap();
            assert_eq!(j.get("name").as_str().unwrap(), "lenet");
            assert!(j.get("param_count").as_usize().unwrap() > 0);
        }
    }
}

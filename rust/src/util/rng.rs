//! Deterministic PRNG (PCG32 + SplitMix64 seeding).
//!
//! Every stochastic choice in the framework — synthetic data, WAN
//! fluctuation draws, batch shuffling, worker jitter — flows through this
//! generator so experiments replay bit-identically under a fixed seed.
//! (No `rand` crate is vendored in this environment; PCG32 is ~30 lines
//! and statistically solid for simulation use.)

/// Permuted congruential generator (PCG-XSH-RR 64/32) with stream selection.
#[derive(Debug, Clone)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

const PCG_MULT: u64 = 6364136223846793005;

/// SplitMix64: used to stretch a user seed into well-mixed PCG init state.
fn splitmix64(x: &mut u64) -> u64 {
    *x = x.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *x;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Pcg32 {
    /// Create a generator from a seed and a stream id; distinct streams are
    /// statistically independent, which lets each component (dataset, link,
    /// worker...) own a private stream derived from the experiment seed.
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut sm = seed;
        let init_state = splitmix64(&mut sm);
        let mut sm2 = stream ^ 0xDA3E_39CB_94B9_5BDB;
        let init_inc = splitmix64(&mut sm2) | 1;
        let mut rng = Pcg32 { state: 0, inc: init_inc };
        rng.state = init_state.wrapping_add(rng.inc);
        rng.next_u32();
        rng
    }

    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [0, 1) as f32.
    pub fn f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform integer in [0, n). Uses Lemire's bounded method (unbiased).
    pub fn below(&mut self, n: u32) -> u32 {
        assert!(n > 0, "below(0)");
        let mut x = self.next_u32();
        let mut m = (x as u64).wrapping_mul(n as u64);
        let mut l = m as u32;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u32();
                m = (x as u64).wrapping_mul(n as u64);
                l = m as u32;
            }
        }
        (m >> 32) as u32
    }

    pub fn usize_below(&mut self, n: usize) -> usize {
        assert!(n > 0 && n <= u32::MAX as usize);
        self.below(n as u32) as usize
    }

    /// Uniform in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    pub fn normal_f32(&mut self) -> f32 {
        self.normal() as f32
    }

    /// Lognormal with E[X] = 1: X = exp(N(-sigma^2/2, sigma)).
    /// Used for WAN fluctuation multipliers (mean-preserving).
    pub fn lognormal_mean1(&mut self, sigma: f64) -> f64 {
        (self.normal() * sigma - 0.5 * sigma * sigma).exp()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.usize_below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Gamma(shape, 1) via Marsaglia–Tsang squeeze (2000). Shapes below 1
    /// use the boost `Gamma(a) = Gamma(a+1) * U^(1/a)`, so Dirichlet
    /// concentration parameters well under 1 (heavy label skew) stay
    /// exact. Used by the data plane's non-IID cohort sharding.
    pub fn gamma(&mut self, shape: f64) -> f64 {
        assert!(shape > 0.0, "gamma shape must be positive, got {shape}");
        if shape < 1.0 {
            let u = self.f64().max(1e-300);
            return self.gamma(shape + 1.0) * u.powf(1.0 / shape);
        }
        let d = shape - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = self.normal();
            let v = 1.0 + c * x;
            if v <= 0.0 {
                continue;
            }
            let v = v * v * v;
            let u = self.f64().max(1e-300);
            let x2 = x * x;
            if u < 1.0 - 0.0331 * x2 * x2 {
                return d * v;
            }
            if u.ln() < 0.5 * x2 + d * (1.0 - v + v.ln()) {
                return d * v;
            }
        }
    }

    /// Dirichlet(alpha, ..., alpha) over `k` components: `k` iid gamma
    /// draws, normalized. Degenerate inputs return the uniform simplex
    /// point so callers never divide by zero.
    pub fn dirichlet_symmetric(&mut self, alpha: f64, k: usize) -> Vec<f64> {
        assert!(k > 0, "dirichlet needs at least one component");
        let draws: Vec<f64> = (0..k).map(|_| self.gamma(alpha)).collect();
        let total: f64 = draws.iter().sum();
        if total <= 0.0 || !total.is_finite() {
            return vec![1.0 / k as f64; k];
        }
        draws.into_iter().map(|g| g / total).collect()
    }

    /// Binomial(n, p) draw. Small `n` runs the exact Bernoulli loop;
    /// large `n` uses the normal approximation (mean np, var np(1-p)),
    /// rounded and clamped to [0, n]. The approximation only engages
    /// where its relative error is far below the simulator's jitter
    /// (np(1-p) >= ~9), so federated dropout draws over 100k-client
    /// cohorts cost O(1) instead of O(n).
    pub fn binomial(&mut self, n: u64, p: f64) -> u64 {
        let p = p.clamp(0.0, 1.0);
        if n == 0 || p == 0.0 {
            return 0;
        }
        if p == 1.0 {
            return n;
        }
        if p > 0.5 {
            return n - self.binomial(n, 1.0 - p);
        }
        let mean = n as f64 * p;
        let var = mean * (1.0 - p);
        if n <= 64 {
            let mut hits = 0u64;
            for _ in 0..n {
                if self.f64() < p {
                    hits += 1;
                }
            }
            return hits;
        }
        if var < 9.0 {
            // Waiting-time (geometric-gap) method: O(np) expected draws,
            // exact, so a 0.01% dropout over a million clients costs ~100
            // draws instead of a million Bernoulli trials.
            let log_q = (1.0 - p).ln();
            let mut hits = 0u64;
            let mut pos = 0u64;
            loop {
                let u = self.f64().max(1e-300);
                let gap = (u.ln() / log_q).floor() as u64;
                pos = pos.saturating_add(gap).saturating_add(1);
                if pos > n {
                    return hits;
                }
                hits += 1;
            }
        }
        let draw = mean + var.sqrt() * self.normal();
        (draw.round().max(0.0) as u64).min(n)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_streams() {
        let mut a = Pcg32::new(42, 0);
        let mut b = Pcg32::new(42, 0);
        for _ in 0..100 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn distinct_streams_differ() {
        let mut a = Pcg32::new(42, 0);
        let mut b = Pcg32::new(42, 1);
        let same = (0..64).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4, "streams should be decorrelated, {same} collisions");
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Pcg32::new(7, 7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_unbiased_small() {
        let mut r = Pcg32::new(1, 2);
        let mut counts = [0u32; 5];
        for _ in 0..50_000 {
            counts[r.below(5) as usize] += 1;
        }
        for &c in &counts {
            assert!((8_000..12_000).contains(&c), "{counts:?}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg32::new(3, 4);
        let n = 50_000;
        let (mut sum, mut sq) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            sum += x;
            sq += x * x;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn lognormal_mean_is_one() {
        let mut r = Pcg32::new(9, 1);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.lognormal_mean1(0.3)).sum::<f64>() / n as f64;
        assert!((mean - 1.0).abs() < 0.02, "mean {mean}");
    }

    #[test]
    fn gamma_moments_match() {
        // Gamma(k, 1) has mean k and variance k; check both regimes of
        // the sampler (boost below 1, squeeze above).
        for &shape in &[0.3, 1.0, 2.5, 9.0] {
            let mut r = Pcg32::new(11, 3);
            let n = 50_000;
            let (mut sum, mut sq) = (0.0, 0.0);
            for _ in 0..n {
                let x = r.gamma(shape);
                assert!(x >= 0.0 && x.is_finite());
                sum += x;
                sq += x * x;
            }
            let mean = sum / n as f64;
            let var = sq / n as f64 - mean * mean;
            assert!((mean - shape).abs() < 0.08 * shape.max(1.0), "shape {shape}: mean {mean}");
            assert!((var - shape).abs() < 0.25 * shape.max(1.0), "shape {shape}: var {var}");
        }
    }

    #[test]
    fn dirichlet_sums_to_one_and_skews_with_alpha() {
        let mut r = Pcg32::new(21, 0);
        let heavy = r.dirichlet_symmetric(0.1, 8);
        assert!((heavy.iter().sum::<f64>() - 1.0).abs() < 1e-12);
        let mut r2 = Pcg32::new(21, 1);
        let flat = r2.dirichlet_symmetric(100.0, 8);
        assert!((flat.iter().sum::<f64>() - 1.0).abs() < 1e-12);
        // Low alpha concentrates mass; high alpha spreads it.
        let max_heavy = heavy.iter().cloned().fold(0.0, f64::max);
        let max_flat = flat.iter().cloned().fold(0.0, f64::max);
        assert!(max_heavy > max_flat, "alpha=0.1 max {max_heavy} vs alpha=100 max {max_flat}");
        assert!(max_flat < 0.25, "alpha=100 over 8 components is near-uniform: {flat:?}");
    }

    #[test]
    fn binomial_matches_moments_in_every_regime() {
        // (n, p) pairs exercising exact loop, geometric-gap, symmetry
        // flip, and the normal approximation.
        for &(n, p) in &[(40u64, 0.3), (1_000_000, 0.000_05), (50, 0.9), (100_000, 0.1)] {
            let mut r = Pcg32::new(17, n ^ 5);
            let trials = 3_000;
            let mut sum = 0.0;
            for _ in 0..trials {
                let x = r.binomial(n, p);
                assert!(x <= n);
                sum += x as f64;
            }
            let mean = sum / trials as f64;
            let expect = n as f64 * p;
            let sd = (expect * (1.0 - p)).sqrt();
            let tol = 4.0 * sd / (trials as f64).sqrt() + 0.05;
            assert!((mean - expect).abs() < tol, "n={n} p={p}: mean {mean} expect {expect}");
        }
        let mut r = Pcg32::new(1, 1);
        assert_eq!(r.binomial(0, 0.5), 0);
        assert_eq!(r.binomial(10, 0.0), 0);
        assert_eq!(r.binomial(10, 1.0), 10);
    }

    #[test]
    fn new_samplers_are_deterministic() {
        let mut a = Pcg32::new(99, 7);
        let mut b = Pcg32::new(99, 7);
        for _ in 0..50 {
            assert_eq!(a.gamma(0.5).to_bits(), b.gamma(0.5).to_bits());
            assert_eq!(a.binomial(10_000, 0.01), b.binomial(10_000, 0.01));
        }
        assert_eq!(
            Pcg32::new(3, 3).dirichlet_symmetric(0.5, 6),
            Pcg32::new(3, 3).dirichlet_symmetric(0.5, 6)
        );
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg32::new(5, 5);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>());
    }
}

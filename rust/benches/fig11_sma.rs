//! Bench: regenerate Fig 11 (+SMA on the self-hosted link, ResNet).
mod common;

fn main() {
    common::banner("fig11_sma");
    let coord = common::coordinator();
    cloudless::exp::sync_exp::fig11(&coord, common::scale_from_args());
}

//! Bench: regenerate TABLE IV + Fig 8 + Fig 9 (elastic scheduling:
//! plans, time/cost decomposition, accuracy convergence).
mod common;

fn main() {
    common::banner("fig8_scheduling (+table4, fig9)");
    let coord = common::coordinator();
    cloudless::exp::scheduling::table4(&coord);
    cloudless::exp::scheduling::fig8_fig9(&coord, common::scale_from_args(), true);
}

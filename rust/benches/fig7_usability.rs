//! Bench: regenerate Fig 7 (usability: Cloudless vs trivial PS, 3 models).
mod common;

fn main() {
    common::banner("fig7_usability");
    let coord = common::coordinator();
    cloudless::exp::usability::fig7(&coord, common::scale_from_args());
}

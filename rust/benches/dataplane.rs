//! Bench: the data-plane placement modes (compute-follows-data /
//! data-follows-compute / joint, plus a replica-seeded joint run) on a
//! 70%-skewed dataset catalog over a 4-cloud heterogeneous WAN with thin
//! Guangzhou links. `--data-placement <spec>` overrides the catalog
//! (e.g. `skewed:8:0.7:r2`).
mod common;

fn main() {
    common::banner("dataplane");
    let coord = common::coordinator();
    let model = std::env::args()
        .skip_while(|a| a != "--model")
        .nth(1)
        .unwrap_or_else(|| "lenet".to_string());
    let spec = std::env::args().skip_while(|a| a != "--data-placement").nth(1);
    cloudless::exp::dataplane_exp::dataplane_compare(
        &coord,
        common::scale_from_args(),
        &model,
        spec.as_deref(),
    );
}

//! Bench: the three data-plane placement modes (compute-follows-data /
//! data-follows-compute / joint) on a 70%-skewed dataset catalog over a
//! 4-cloud heterogeneous WAN with thin Guangzhou links.
mod common;

fn main() {
    common::banner("dataplane");
    let coord = common::coordinator();
    let model = std::env::args()
        .skip_while(|a| a != "--model")
        .nth(1)
        .unwrap_or_else(|| "lenet".to_string());
    cloudless::exp::dataplane_exp::dataplane_compare(
        &coord,
        common::scale_from_args(),
        &model,
        None,
    );
}

//! Bench: regenerate the paper's TABLE I (device speed quantification).
mod common;

fn main() {
    common::banner("table1_devices");
    cloudless::exp::motivation::table1();
}

//! Bench: fleet-scale simulation throughput — hundreds of synthetic jobs
//! on a 16-region GPU fleet, reporting discrete events executed per wall
//! second plus the per-worker vs cohort-aggregation equivalence leg (see
//! docs/EXPERIMENTS.md). `--full` runs the 1000-job trace.
mod common;

fn main() {
    common::banner("fleetscale");
    let coord = common::coordinator();
    cloudless::exp::fleetscale_exp::fleetscale(&coord, common::scale_from_args(), 0, 0)
        .expect("fleetscale bench");
}

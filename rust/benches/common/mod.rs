//! Shared bench plumbing (no criterion offline): each bench target is a
//! `harness = false` binary that regenerates one paper table/figure via
//! the `exp` drivers, plus `time_median` for the micro benches.

use cloudless::coordinator::Coordinator;
use cloudless::exp::Scale;

pub fn coordinator() -> Coordinator {
    let dir = std::env::var("CLOUDLESS_ARTIFACTS")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|_| std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts"));
    Coordinator::new(dir).expect("PJRT runtime (run `make artifacts` first)")
}

#[allow(dead_code)]
pub fn scale_from_args() -> Scale {
    let full = std::env::args().any(|a| a == "--full");
    Scale::from_flag(full)
}

/// Median wall seconds of `f` over `reps` runs (after one warmup).
#[allow(dead_code)]
pub fn time_median<F: FnMut()>(reps: usize, mut f: F) -> f64 {
    f();
    let mut times: Vec<f64> = (0..reps.max(1))
        .map(|_| {
            let t0 = std::time::Instant::now();
            f();
            t0.elapsed().as_secs_f64()
        })
        .collect();
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    times[times.len() / 2]
}

/// Pretty banner for bench output.
#[allow(dead_code)]
pub fn banner(name: &str) {
    println!("\n==== bench: {name} ====");
}

//! Bench: design-choice ablations beyond the paper (DESIGN.md §4):
//! sync-frequency sweep, WAN fluctuation severity, 3-region ring,
//! worker granularity, drop-probability failure injection.
mod common;

fn main() {
    common::banner("ablations");
    let coord = common::coordinator();
    cloudless::exp::ablations::all(&coord, common::scale_from_args(), "lenet");
}

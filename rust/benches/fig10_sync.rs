//! Bench: regenerate Fig 10 (ASGD vs ASGD-GA vs AMA at freq {1,4,8}).
mod common;

fn main() {
    common::banner("fig10_sync");
    let coord = common::coordinator();
    cloudless::exp::sync_exp::fig10(&coord, common::scale_from_args());
}

//! Bench: regenerate Fig 3 (WAN communication share, ResNet18 @100 Mbps).
mod common;

fn main() {
    common::banner("fig3_wan_share");
    cloudless::exp::motivation::fig3();
}

//! Bench: static vs elastic re-scheduling under injected mid-run resource
//! churn and WAN bandwidth fluctuation on a 4-cloud heterogeneous WAN.
mod common;

fn main() {
    common::banner("elastic");
    let coord = common::coordinator();
    let model = std::env::args()
        .skip_while(|a| a != "--model")
        .nth(1)
        .unwrap_or_else(|| "lenet".to_string());
    cloudless::exp::elastic_exp::elastic_compare(&coord, common::scale_from_args(), &model);
}

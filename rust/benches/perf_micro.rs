//! Micro benchmarks for the L3 hot paths (§Perf in EXPERIMENTS.md):
//!
//! - PJRT execute round-trip per model (the per-iteration floor);
//! - PS vector ops: native Rust loops vs the Pallas/PJRT artifacts
//!   (the `vecops_backend` ablation);
//! - DES event throughput (events/second the engine can retire);
//! - WAN fabric transfer scheduling throughput.

mod common;

use cloudless::runtime::{vecops, Tensor};
use cloudless::sim::Sim;

fn main() {
    common::banner("perf_micro");
    let coord = common::coordinator();
    let rt = coord.runtime();

    // ---- PJRT execute round-trips ------------------------------------
    println!("PJRT train_step round-trip (median of 10):");
    for model in ["lenet", "resnet", "deepfm"] {
        let m = rt.load_model(model).unwrap();
        let (ds, _) = cloudless::data::generate(&m.meta, m.meta.batch_size, 1, 0);
        let idxs: Vec<usize> = (0..m.meta.batch_size).collect();
        let (x, y) = ds.batch(&idxs, &m.meta);
        let params = m.init_params.clone();
        let t = common::time_median(10, || {
            let _ = m.train_step(&params, &x, &y).unwrap();
        });
        println!("  {model:<8} {:>8.2} ms  ({} params)", t * 1e3, m.meta.param_count);
    }

    // ---- input-conversion share: literal args vs pre-uploaded buffers --
    {
        let exe = rt.compile_artifact("lenet_train_step.hlo.txt").unwrap();
        let m = rt.load_model("lenet").unwrap();
        let p = m.init_params.clone();
        let x = vec![0.1f32; 64 * 784];
        let y = vec![1i32; 64];
        let t_lit = common::time_median(10, || {
            let outs = exe
                .run(&[
                    xla::Literal::vec1(&p),
                    xla::Literal::vec1(&x).reshape(&[64, 28, 28, 1]).unwrap(),
                    xla::Literal::vec1(&y),
                ])
                .unwrap();
            std::hint::black_box(outs.len());
        });
        let client = xla::PjRtClient::cpu().unwrap();
        let proto = xla::HloModuleProto::from_text_file(
            coord.runtime().artifacts_dir.join("lenet_train_step.hlo.txt"),
        )
        .unwrap();
        let raw = client.compile(&xla::XlaComputation::from_proto(&proto)).unwrap();
        let bp = client.buffer_from_host_buffer(&p, &[61706], None).unwrap();
        let bx = client.buffer_from_host_buffer(&x, &[64, 28, 28, 1], None).unwrap();
        let by = client.buffer_from_host_buffer(&y, &[64], None).unwrap();
        let t_buf = common::time_median(10, || {
            let r = raw.execute_b::<&xla::PjRtBuffer>(&[&bp, &bx, &by]).unwrap();
            std::hint::black_box(r.len());
        });
        println!(
            "lenet step: literal-args(full) {:.2} ms vs pre-uploaded buffers {:.2} ms (input conv + output copy share: {:.0}%)",
            t_lit * 1e3,
            t_buf * 1e3,
            (1.0 - t_buf / t_lit) * 100.0
        );
    }

    // ---- PS vector ops: native vs PJRT(Pallas) ------------------------
    let m = rt.load_model("deepfm").unwrap();
    let p0 = m.init_params.clone();
    println!("PS vecops on deepfm-sized vectors (P={}, median of 20):", p0.len());
    let g: Vec<f32> = (0..p0.len()).map(|i| (i % 7) as f32 * 0.01).collect();
    let t_native = common::time_median(20, || {
        let mut p = p0.clone();
        vecops::sgd_apply_inplace(&mut p, &g, 0.01);
        std::hint::black_box(&p);
    });
    let t_pjrt = common::time_median(20, || {
        let _ = m.sgd_apply(&p0, &g, 0.01).unwrap();
    });
    println!("  sgd_apply  native {:>8.3} ms   pjrt(pallas) {:>8.3} ms", t_native * 1e3, t_pjrt * 1e3);
    let t_native_avg = common::time_median(20, || {
        let mut a = p0.clone();
        vecops::average_inplace(&mut a, &g, 0.5);
        std::hint::black_box(&a);
    });
    let t_pjrt_avg = common::time_median(20, || {
        let _ = m.model_average(&p0, &g, 0.5).unwrap();
    });
    println!("  average    native {:>8.3} ms   pjrt(pallas) {:>8.3} ms", t_native_avg * 1e3, t_pjrt_avg * 1e3);

    // ---- eval round-trip ----------------------------------------------
    let (ds, _) = cloudless::data::generate(&m.meta, m.meta.batch_size, 1, 0);
    let idxs: Vec<usize> = (0..m.meta.batch_size).collect();
    let (x, y) = ds.batch(&idxs, &m.meta);
    let t_eval = common::time_median(10, || {
        let _ = m.eval_batch(&p0, &x, &y).unwrap();
    });
    println!("  eval_batch(deepfm) {:.3} ms", t_eval * 1e3);

    // ---- batch materialization (data hot path) ------------------------
    let lenet = rt.load_model("lenet").unwrap();
    let (big_ds, _) = cloudless::data::generate(&lenet.meta, 4096, 1, 0);
    let idxs64: Vec<usize> = (0..64).collect();
    let t_batch = common::time_median(50, || {
        let (x, y) = big_ds.batch(&idxs64, &lenet.meta);
        std::hint::black_box((x.num_elements(), y.num_elements()));
    });
    println!("  batch materialization (lenet B=64) {:.3} ms", t_batch * 1e3);
    let _ = Tensor::f32(vec![0.0], vec![1]);

    // ---- DES event throughput -----------------------------------------
    struct W {
        count: u64,
    }
    let t_des = common::time_median(5, || {
        let mut sim: Sim<W> = Sim::new();
        let mut w = W { count: 0 };
        fn tick(sim: &mut Sim<W>, w: &mut W) {
            w.count += 1;
            if w.count % 1 != 0 || w.count < 1_000_000 {
                if w.count < 1_000_000 {
                    sim.schedule(0.001, tick);
                }
            }
        }
        for _ in 0..64 {
            sim.schedule(0.0, tick);
        }
        sim.run(&mut w);
        std::hint::black_box(w.count);
    });
    println!("DES: 1M chained events in {:.0} ms ({:.1} M events/s)", t_des * 1e3, 1.0 / t_des);

    // ---- WAN fabric scheduling ----------------------------------------
    let t_net = common::time_median(5, || {
        let mut fabric = cloudless::net::Fabric::new(1);
        fabric.add_duplex(0, 1, cloudless::net::LinkSpec::wan_100mbps());
        let mut t = 0.0;
        for i in 0..1_000_000u64 {
            let tr = fabric.transfer((i % 2) as usize, ((i + 1) % 2) as usize, 1_000, t);
            t = tr.start.max(t) + 1e-5;
        }
        std::hint::black_box(fabric.total_wan_bytes());
    });
    println!("WAN fabric: 1M transfers in {:.0} ms ({:.1} M transfers/s)", t_net * 1e3, 1.0 / t_net);
}

//! Bench: regenerate Fig 2 (load-imbalance motivation, LeNet).
mod common;

fn main() {
    common::banner("fig2_motivation");
    let coord = common::coordinator();
    cloudless::exp::motivation::fig2(&coord, common::scale_from_args());
}

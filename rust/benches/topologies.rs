//! Bench: compare Ring vs Hierarchical vs BandwidthTree sync topologies
//! (wall-clock + WAN bytes) on a 4-cloud heterogeneous WAN.
mod common;

fn main() {
    common::banner("topologies");
    let coord = common::coordinator();
    cloudless::exp::topology_exp::topology_compare(&coord, common::scale_from_args(), "lenet");
}

//! Bench: concurrent training workflows over one shared 4-cloud
//! inventory — FIFO vs fair-share vs cost-aware leasing on a Poisson
//! job-arrival trace (see docs/EXPERIMENTS.md).
mod common;

use cloudless::coordinator::fleet::MultiJobParams;

fn main() {
    common::banner("multijob");
    let coord = common::coordinator();
    let model = std::env::args()
        .skip_while(|a| a != "--model")
        .nth(1)
        .unwrap_or_else(|| "lenet".to_string());
    let params = MultiJobParams::default();
    cloudless::exp::multijob_exp::multijob_compare(
        &coord,
        common::scale_from_args(),
        &model,
        &params,
    );
}

//! End-to-end validation driver: train a decoder-only transformer LM on
//! a synthetic corpus across two simulated cloud regions through the
//! FULL stack — control plane, serverless workflows, PS communicators
//! over the modeled WAN, ASGD-GA sync, and real PJRT compute for every
//! gradient — logging the loss curve. (Stack layering:
//! docs/ARCHITECTURE.md.)
//!
//! ```text
//! cargo run --release --example train_transformer [--steps N] [--model transformer100m]
//! ```
//!
//! Defaults: the ~6.5M-parameter config, a few hundred steps. The ~100M
//! config (`make artifacts-100m` first) is supported via --model
//! transformer100m --steps 3 (each step is ~30 s of real single-core
//! compute; docs/EXPERIMENTS.md maps every recorded experiment).

use cloudless::cloud::devices::Device;
use cloudless::cloud::CloudEnv;
use cloudless::coordinator::{Coordinator, JobSpec, SchedulingMode};
use cloudless::sync::{Strategy, SyncConfig};
use cloudless::util::args::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let model = args.get_or("model", "transformer").to_string();
    let steps = args.usize("steps", 300);
    let artifacts = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let coord = Coordinator::new(artifacts)?;

    // Corpus windows sized so `epochs` passes over the two shards total
    // exactly `steps` worker iterations (curve granularity: 1 eval/epoch).
    let rt_meta = coord.runtime().load_model(&model)?.meta.clone();
    let b = rt_meta.batch_size;
    println!(
        "e2e transformer: {} ({} params, batch {}, seq {})",
        model, rt_meta.param_count, b, rt_meta.x_shape[0]
    );
    let epochs = args.usize("epochs", 10).max(1);
    let n_windows = ((steps * b) / epochs).max(2 * b);

    // 2 regions; each worker function drives a V100-class virtual device
    // so the virtual clock reflects an accelerator deployment.
    let env = CloudEnv::new(vec![
        cloudless::cloud::Region::new(0, "us-east", vec![(Device::V100, 1)], n_windows / 2),
        cloudless::cloud::Region::new(1, "eu-west", vec![(Device::V100, 1)], n_windows / 2),
    ]);

    let mut spec = JobSpec::new(&model, env);
    spec.scheduling = SchedulingMode::Greedy;
    spec.train.n_train = n_windows;
    spec.train.n_eval = (b * 8).min(256);
    spec.train.epochs = epochs;
    spec.train.sync = SyncConfig::new(Strategy::AsgdGa, 4);
    spec.train.eval_every = 1;

    let wall = std::time::Instant::now();
    let report = coord.submit(&spec)?;
    println!("\n{}", report.summary());
    println!("wall time: {:.1}s  pjrt executions: {}", wall.elapsed().as_secs_f64(), report.pjrt_executions);
    println!("\nloss curve (virtual time, partition-0 evals):");
    for pt in &report.curve {
        println!("  t={:>9.1}s  epoch={}  loss={:.4}  token-acc={:.4}", pt.t, pt.epoch, pt.loss, pt.accuracy);
    }
    println!("\nfinal: loss={:.4} token-acc={:.4}", report.final_loss, report.final_accuracy);
    for p in &report.partitions {
        println!(
            "  {:<8} steps={:<5} syncs={}/{} staleness={:.2}",
            p.region, p.steps, p.syncs_sent, p.syncs_received, p.mean_staleness
        );
    }
    Ok(())
}

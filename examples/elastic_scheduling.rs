//! Scenario: the paper's headline scheduling story, end to end.
//!
//! Two heterogeneous regions with a 2:1 data skew (TABLE IV case 3). The
//! greedy baseline rents all 24 cores; the elastic scheduler (Algorithm 1)
//! rents 12:4, matching the straggler's load power. Both jobs then train
//! ResNet-lite for real, and we compare waiting time, cost and accuracy.
//!
//! ```text
//! cargo run --release --example elastic_scheduling [epochs]
//! ```
//!
//! The scheduling story continues past this one-shot plan: the live
//! re-scheduling loop (`exp --id elastic`) and the multi-job fleet
//! (`exp --id multijob`) are mapped in docs/EXPERIMENTS.md.

use cloudless::cloud::devices::Device;
use cloudless::cloud::CloudEnv;
use cloudless::coordinator::{Coordinator, JobSpec, SchedulingMode};
use cloudless::sched::load_power;
use cloudless::sync::SyncConfig;

fn main() -> anyhow::Result<()> {
    let artifacts = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let coord = Coordinator::new(artifacts)?;
    let epochs: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(4);

    let n_train = 2048;
    let env = CloudEnv::tencent_two_region(Device::Skylake, n_train * 2 / 3, n_train / 3);

    // --- what the scheduler sees -------------------------------------
    println!("environment:");
    for r in &env.regions {
        let full = env.greedy_plan()[r.id].clone();
        println!(
            "  {:<10} inventory={:?} data={} samples  LP(full)={:.6}",
            r.name,
            r.inventory,
            r.data_samples,
            // Total since the data plane landed: None = a data-less
            // region (not possible in this two-region setup).
            load_power(&full, r.data_samples).expect("both regions hold data")
        );
    }
    let plan = coord.plan(&env);
    println!("\nelastic plan (straggler = {}):", env.regions[plan.straggler].name);
    for (a, r) in plan.allocations.iter().zip(&env.regions) {
        println!("  {:<10} {:?}", r.name, a.units);
    }

    // --- run both plans ----------------------------------------------
    let mut results = Vec::new();
    for mode in [SchedulingMode::Greedy, SchedulingMode::Elastic] {
        let mut spec = JobSpec::new("resnet", env.clone());
        spec.train.epochs = epochs;
        spec.train.n_train = n_train;
        spec.train.n_eval = 512;
        spec.train.sync = SyncConfig::baseline();
        spec.scheduling = mode;
        let report = coord.submit(&spec)?;
        println!("\n{mode:?}: {}", report.summary());
        for p in &report.partitions {
            println!(
                "  {:<10} units={:<2} finish={:.0}s waiting={:.0}s",
                p.region, p.units, p.local_finish, p.waiting
            );
        }
        results.push(report);
    }

    let (greedy, elastic) = (&results[0], &results[1]);
    println!("\nsummary:");
    println!(
        "  waiting: {:.0}s -> {:.0}s ({:.1}% less)",
        greedy.total_waiting(),
        elastic.total_waiting(),
        (1.0 - elastic.total_waiting() / greedy.total_waiting().max(1e-9)) * 100.0
    );
    println!(
        "  compute cost: ${:.4} -> ${:.4} ({:.1}% less; paper band: 9.2%-24.0%)",
        greedy.compute_cost,
        elastic.compute_cost,
        (1.0 - elastic.compute_cost / greedy.compute_cost) * 100.0
    );
    println!("  WAN cost:     ${:.4} -> ${:.4}", greedy.wan_cost, elastic.wan_cost);
    println!(
        "  accuracy: {:.4} (greedy) vs {:.4} (elastic)",
        greedy.final_accuracy, elastic.final_accuracy
    );
    Ok(())
}

//! Scenario: the paper's WAN synchronization strategies, side by side.
//!
//! DeepFM is the communication-heavy workload (2.4 MB of gradients per
//! sync): the ASGD baseline (sync every iteration) saturates the PS
//! communicator, while ASGD-GA and AMA relieve it by syncing every 8
//! local updates. SMA runs on the self-hosted link profile, trading time
//! for the best accuracy.
//!
//! ```text
//! cargo run --release --example sync_strategies [epochs]
//! ```
//!
//! Strategy semantics and the `compression` codecs that ride on them
//! are documented (with compiled examples) in docs/CONFIG.md.

use cloudless::cloud::devices::Device;
use cloudless::cloud::CloudEnv;
use cloudless::coordinator::{Coordinator, JobSpec, SchedulingMode};
use cloudless::net::LinkSpec;
use cloudless::sync::{Strategy, SyncConfig};

fn main() -> anyhow::Result<()> {
    let artifacts = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let coord = Coordinator::new(artifacts)?;
    let epochs: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(6);

    let n_train = 16384;
    let env = CloudEnv::tencent_two_region(Device::Skylake, n_train / 2, n_train / 2);

    let settings: Vec<(&str, SyncConfig, LinkSpec)> = vec![
        ("ASGD f1 (baseline)", SyncConfig::baseline(), LinkSpec::wan_100mbps()),
        ("ASGD-GA f8", SyncConfig::new(Strategy::AsgdGa, 8), LinkSpec::wan_100mbps()),
        ("AMA f8", SyncConfig::new(Strategy::Ama, 8), LinkSpec::wan_100mbps()),
        ("SMA f8 (self-hosted)", SyncConfig::new(Strategy::Sma, 8), LinkSpec::self_hosted()),
    ];

    let mut baseline_time = None;
    println!("{:<22} {:>8} {:>9} {:>10} {:>10} {:>10}", "strategy", "time", "speedup", "WAN MB", "comm s", "final acc");
    for (label, sync, link) in settings {
        let mut spec = JobSpec::new("deepfm", env.clone());
        spec.train.epochs = epochs;
        spec.train.n_train = n_train;
        spec.train.n_eval = 4096;
        spec.train.sync = sync;
        spec.train.link = link;
        spec.scheduling = SchedulingMode::Greedy;
        let r = coord.submit(&spec)?;
        let base = *baseline_time.get_or_insert(r.total_time);
        println!(
            "{:<22} {:>7.0}s {:>8.2}x {:>10.1} {:>9.0}s {:>10.4}",
            label,
            r.total_time,
            base / r.total_time,
            r.wan_bytes as f64 / 1e6,
            r.total_wan_time(),
            r.final_accuracy
        );
    }
    println!("\n(paper: ASGD-GA/AMA up to 1.7x on DeepFM; SMA ≈ baseline time, best accuracy)");
    Ok(())
}

//! Quickstart: train LeNet across two simulated cloud regions with
//! ASGD-GA synchronization and print the run report.
//!
//! ```text
//! make artifacts && cargo run --release --example quickstart
//! ```
//!
//! Layer map: docs/ARCHITECTURE.md. Every config key / CLI flag used
//! below: docs/CONFIG.md.

use cloudless::cloud::devices::Device;
use cloudless::cloud::CloudEnv;
use cloudless::runtime::PjrtRuntime;
use cloudless::sched::optimal_matching;
use cloudless::sync::{Strategy, SyncConfig};
use cloudless::train::{run_geo_training, TrainConfig};

fn main() -> anyhow::Result<()> {
    let artifacts = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let rt = PjrtRuntime::new(artifacts)?;
    println!("PJRT platform: {}", rt.platform());

    // Two Tencent-like regions: Shanghai (Cascade Lake) and Chongqing
    // (Skylake), with a 2:1 data split — the paper's Table IV case 3.
    let env = CloudEnv::tencent_two_region(Device::Skylake, 2048, 1024);

    // The elastic scheduler picks the load-balanced plan (12:4 cores).
    let plan = optimal_matching(&env);
    println!("elastic plan:");
    for (alloc, region) in plan.allocations.iter().zip(&env.regions) {
        println!(
            "  {:<10} {:?} (LP full={:.5} planned={:.5})",
            region.name,
            alloc.units,
            plan.full_lp[region.id],
            plan.planned_lp[region.id]
        );
    }

    // Train LeNet for a few epochs with ASGD-GA (sync every 4 updates).
    let mut cfg = TrainConfig::new("lenet");
    cfg.epochs = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(4);
    cfg.n_train = 3072;
    cfg.n_eval = 1024;
    cfg.sync = SyncConfig::new(Strategy::AsgdGa, 4);
    let report = run_geo_training(&rt, &env, plan.allocations, cfg)?;

    println!("\n{}", report.summary());
    println!("\naccuracy curve:");
    for pt in &report.curve {
        println!("  t={:>8.1}s epoch={} acc={:.4} loss={:.4}", pt.t, pt.epoch, pt.accuracy, pt.loss);
    }
    println!("\nper-partition:");
    for p in &report.partitions {
        println!(
            "  {:<10} units={:<2} steps={:<5} finish={:.1}s wait={:.1}s comm_wait={:.1}s staleness={:.2}",
            p.region, p.units, p.steps, p.local_finish, p.waiting, p.comm_wait, p.mean_staleness
        );
    }
    Ok(())
}

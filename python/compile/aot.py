"""AOT lowering: JAX (L2, calling L1 Pallas kernels) -> HLO text artifacts.

For every model this emits, under `artifacts/`:

    {model}_train_step.hlo.txt  (params f32[P], x, y) -> (grads f32[P], loss f32[])
    {model}_eval.hlo.txt        (params f32[P], x, y) -> (loss_sum, correct)
    {model}_sgd_apply.hlo.txt   (p f32[P], g f32[P], lr f32[]) -> p'    [Pallas]
    {model}_avg.hlo.txt         (a f32[P], b f32[P], w f32[])  -> avg   [Pallas]
    {model}_acc.hlo.txt         (acc f32[P], g f32[P])         -> acc'  [Pallas]
    {model}_init.bin            f32 LE initial parameters (P floats)
    {model}_meta.json           geometry the Rust side needs (P, batch, dims...)

plus `kernel_matmul.hlo.txt`, the raw L1 Pallas matmul (256x256x256) used by
the Rust runtime smoke tests and the L1 block-shape bench.

Compute-path note (see models/common.py): train/eval graphs default to the
XLA path for the conv models and to the Pallas path for DeepFM — on the
1-core CPU PJRT this keeps the Rust experiment suite inside its budget —
while the PS-side vector ops above are always the Pallas kernels, so every
model's artifact set contains Pallas-lowered HLO. Override with --compute.

Interchange format is HLO **text**, NOT a serialized HloModuleProto: jax
>= 0.5 emits protos with 64-bit instruction ids which xla_extension 0.5.1
(what the `xla` crate links) rejects (`proto.id() <= INT_MAX`); the text
parser reassigns ids, so text round-trips cleanly. Lowered with
return_tuple=True; the Rust runtime unwraps the tuple.

Python runs ONCE at build time (`make artifacts`); the Rust binary is
self-contained afterwards.
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import numpy as np
from jax._src.lib import xla_client as xc

from compile.model import DEFAULT_MODELS, get_model
from compile.models.common import Model

#: Per-model default compute path for the train/eval graphs (see docstring).
COMPUTE_DEFAULTS = {"deepfm": "pallas"}


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _avals(model: Model):
    import jax.numpy as jnp

    p = jax.ShapeDtypeStruct((model.param_count,), jnp.float32)
    x_np, y_np = model.example_batch()
    x = jax.ShapeDtypeStruct(x_np.shape, x_np.dtype)
    y = jax.ShapeDtypeStruct(y_np.shape, y_np.dtype)
    return p, x, y


def lower_model(model: Model, out_dir: str, seed: int = 0, verbose: bool = True,
                compute: str | None = None):
    """Lower train/eval/vecop entry points + write init params and metadata."""
    import jax.numpy as jnp

    from compile.kernels import grad_accumulate, model_average, sgd_apply

    os.makedirs(out_dir, exist_ok=True)
    os.environ["CLOUDLESS_COMPUTE"] = compute or COMPUTE_DEFAULTS.get(model.name, "xla")
    p, x, y = _avals(model)
    scalar = jax.ShapeDtypeStruct((), jnp.float32)

    entries = [
        ("train_step", model.train_step, (p, x, y)),
        ("eval", model.eval_step, (p, x, y)),
        # PS-side vector ops: always the L1 Pallas kernels.
        ("sgd_apply", sgd_apply, (p, p, scalar)),
        ("avg", model_average, (p, p, scalar)),
        ("acc", grad_accumulate, (p, p)),
    ]
    for entry, fn, avals in entries:
        lowered = jax.jit(fn).lower(*avals)
        text = to_hlo_text(lowered)
        path = os.path.join(out_dir, f"{model.name}_{entry}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        if verbose:
            print(f"  {path}: {len(text)/1e6:.2f} MB of HLO text")

    init = model.init_flat(seed)
    init_path = os.path.join(out_dir, f"{model.name}_init.bin")
    init.tofile(init_path)

    meta = {
        "name": model.name,
        "param_count": model.param_count,
        "batch_size": model.batch_size,
        "x_shape": list(model.x_shape),
        "x_dtype": model.x_dtype,
        "y_dtype": model.y_dtype,
        "num_classes": model.num_classes,
        "param_bytes": model.param_count * 4,
        "specs": [{"name": s.name, "shape": list(s.shape)} for s in model.specs],
        "meta": model.meta,
        "init_seed": seed,
        "entry_points": {
            "train_step": f"{model.name}_train_step.hlo.txt",
            "eval": f"{model.name}_eval.hlo.txt",
        },
    }
    meta["compute"] = os.environ["CLOUDLESS_COMPUTE"]
    meta_path = os.path.join(out_dir, f"{model.name}_meta.json")
    with open(meta_path, "w") as f:
        json.dump(meta, f, indent=2)
    if verbose:
        print(f"  {init_path}: {model.param_count} params "
              f"({model.param_count * 4 / 1e6:.2f} MB)")
    return meta


def lower_kernel_demo(out_dir: str, n: int = 256, verbose: bool = True):
    """Lower the raw Pallas matmul (n x n x n) for Rust runtime smoke tests."""
    import jax.numpy as jnp

    from compile.kernels import matmul

    spec = jax.ShapeDtypeStruct((n, n), jnp.float32)
    lowered = jax.jit(matmul).lower(spec, spec)
    path = os.path.join(out_dir, "kernel_matmul.hlo.txt")
    with open(path, "w") as f:
        f.write(to_hlo_text(lowered))
    if verbose:
        print(f"  {path}: Pallas matmul {n}x{n}x{n}")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="artifact output dir")
    ap.add_argument(
        "--models",
        default=",".join(DEFAULT_MODELS),
        help="comma-separated model names (see compile.model.list_models)",
    )
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--compute", default=None, choices=(None, "pallas", "xla"),
                    help="override the per-model compute-path default")
    args = ap.parse_args()

    names = [n for n in args.models.split(",") if n]
    os.makedirs(args.out, exist_ok=True)
    lower_kernel_demo(args.out)
    for name in names:
        print(f"lowering {name} ...")
        lower_model(get_model(name), args.out, seed=args.seed, compute=args.compute)
    print(f"artifacts written to {os.path.abspath(args.out)}")


if __name__ == "__main__":
    main()

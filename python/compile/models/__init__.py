"""L2: JAX model zoo (LeNet / ResNet-lite / DeepFM / Transformer).

Every model follows the flat-parameter convention of `common.Model`:
the Rust coordinator only ever sees `f32[P]` parameter / gradient vectors,
and the jitted graphs do all flatten/unflatten internally.
"""

"""LeNet-5 (28x28x1 -> 10 classes), the paper's smallest workload (~61k params,
~0.24 MB of gradients vs the paper's reported 0.4 MB TF graph).

Layout: conv5x5(1->6, SAME) -> avgpool2 -> conv5x5(6->16, VALID) -> avgpool2
-> fc 400->120 -> fc 120->84 -> fc 84->10. All conv/fc FLOPs route through
the L1 Pallas matmul kernel (conv via im2col).
"""

from __future__ import annotations

from compile.models.common import (
    Model,
    ParamSpec,
    avg_pool,
    conv2d_im2col,
    dense,
    softmax_xent,
)

NUM_CLASSES = 10
X_SHAPE = (28, 28, 1)

SPECS = (
    ParamSpec("conv1_w", (5, 5, 1, 6)),
    ParamSpec("conv1_b", (6,), "zeros"),
    ParamSpec("conv2_w", (5, 5, 6, 16)),
    ParamSpec("conv2_b", (16,), "zeros"),
    ParamSpec("fc1_w", (400, 120)),
    ParamSpec("fc1_b", (120,), "zeros"),
    ParamSpec("fc2_w", (120, 84)),
    ParamSpec("fc2_b", (84,), "zeros"),
    ParamSpec("fc3_w", (84, NUM_CLASSES), "glorot"),
    ParamSpec("fc3_b", (NUM_CLASSES,), "zeros"),
)


def apply(p, x):
    """x: [B, 28, 28, 1] -> logits [B, 10]."""
    h = conv2d_im2col(x, p["conv1_w"], p["conv1_b"], padding="SAME", act="relu")
    h = avg_pool(h)  # 14x14x6
    h = conv2d_im2col(h, p["conv2_w"], p["conv2_b"], padding="VALID", act="relu")
    h = avg_pool(h)  # 5x5x16
    h = h.reshape(h.shape[0], -1)  # 400
    h = dense(h, p["fc1_w"], p["fc1_b"], act="relu")
    h = dense(h, p["fc2_w"], p["fc2_b"], act="relu")
    return dense(h, p["fc3_w"], p["fc3_b"], act="linear")


def loss_and_metrics(p, x, y):
    return softmax_xent(apply(p, x), y, NUM_CLASSES)


def build(batch_size: int = 64) -> Model:
    return Model(
        name="lenet",
        specs=SPECS,
        loss_and_metrics=loss_and_metrics,
        batch_size=batch_size,
        x_shape=X_SHAPE,
        x_dtype="f32",
        y_dtype="i32",
        num_classes=NUM_CLASSES,
    )

"""Flat-parameter model convention shared by every L2 model.

The Rust L3 coordinator treats model state as an opaque `f32[P]` vector:
PS state, gradients, accumulated gradients and averaged models are all flat
vectors, which makes the PS hot path (axpy-style ops) trivial and shape-
agnostic. The jitted train/eval graphs do flatten/unflatten internally, so
the boundary artifacts have signatures:

    train_step(params f32[P], x, y) -> (grads f32[P], loss f32[])
    eval_step (params f32[P], x, y) -> (loss_sum f32[], correct f32[])

`ParamSpec` records the parameter tree layout; `Model` bundles the specs
with the apply/loss functions and dataset geometry.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    """One named parameter tensor: shape + initializer family."""

    name: str
    shape: Tuple[int, ...]
    init: str = "he"  # he | glorot | zeros | normal(0.02) | embed

    @property
    def size(self) -> int:
        return int(np.prod(self.shape))


@dataclasses.dataclass(frozen=True)
class Model:
    """A flat-parameter model: specs + pure apply/loss + dataset geometry."""

    name: str
    specs: Tuple[ParamSpec, ...]
    # loss_and_metrics(params_dict, x, y) -> (mean_loss, correct_count)
    loss_and_metrics: Callable
    batch_size: int
    x_shape: Tuple[int, ...]  # per-example input shape
    x_dtype: str  # "f32" | "i32"
    y_dtype: str  # "i32" | "f32"
    num_classes: int  # 0 for regression-style / LM targets
    # Extra dataset geometry the Rust data generators need (vocab sizes...).
    meta: Dict = dataclasses.field(default_factory=dict)

    @property
    def param_count(self) -> int:
        return sum(s.size for s in self.specs)

    # ---- flat <-> tree -------------------------------------------------
    def unflatten(self, flat: jnp.ndarray) -> Dict[str, jnp.ndarray]:
        out, off = {}, 0
        for s in self.specs:
            out[s.name] = jax.lax.dynamic_slice_in_dim(flat, off, s.size).reshape(s.shape)
            off += s.size
        return out

    @staticmethod
    def flatten(tree: Dict[str, jnp.ndarray], specs: Sequence[ParamSpec]) -> jnp.ndarray:
        return jnp.concatenate([tree[s.name].reshape(-1) for s in specs])

    # ---- entry points (what aot.py lowers) -----------------------------
    def loss_flat(self, flat, x, y):
        loss, _ = self.loss_and_metrics(self.unflatten(flat), x, y)
        return loss

    def train_step(self, flat, x, y):
        """(params, x, y) -> (grads f32[P], loss f32[])."""
        loss, grads = jax.value_and_grad(self.loss_flat)(flat, x, y)
        return grads, loss

    def eval_step(self, flat, x, y):
        """(params, x, y) -> (loss_sum f32[], correct f32[])."""
        loss, correct = self.loss_and_metrics(self.unflatten(flat), x, y)
        b = x.shape[0]
        return loss * b, correct

    # ---- init ----------------------------------------------------------
    def init_flat(self, seed: int = 0) -> np.ndarray:
        rng = np.random.default_rng(seed)
        chunks = []
        for s in self.specs:
            chunks.append(_init_tensor(rng, s).reshape(-1))
        return np.concatenate(chunks).astype(np.float32)

    def example_batch(self, seed: int = 0):
        """A deterministic example batch with the right shapes/dtypes
        (used as lowering avals and in tests)."""
        rng = np.random.default_rng(seed + 1)
        b = self.batch_size
        if self.x_dtype == "f32":
            x = rng.standard_normal((b, *self.x_shape), dtype=np.float32)
        else:
            highs = self.meta.get("vocab_sizes")
            if highs is None:
                high = self.meta.get("vocab", 2)
                x = rng.integers(0, high, size=(b, *self.x_shape)).astype(np.int32)
            else:
                cols = [rng.integers(0, h, size=(b, 1)) for h in highs]
                x = np.concatenate(cols, axis=1).astype(np.int32)
        if self.y_dtype == "i32":
            if self.name == "transformer" or self.meta.get("lm", False):
                y = rng.integers(0, self.meta["vocab"], size=(b, *self.x_shape)).astype(np.int32)
            else:
                y = rng.integers(0, max(self.num_classes, 2), size=(b,)).astype(np.int32)
        else:
            y = rng.integers(0, 2, size=(b,)).astype(np.float32)
        return x, y


def _init_tensor(rng: np.random.Generator, s: ParamSpec) -> np.ndarray:
    shape = s.shape
    if s.init == "zeros":
        return np.zeros(shape, np.float32)
    if s.init == "normal":
        return (0.02 * rng.standard_normal(shape)).astype(np.float32)
    if s.init == "embed":
        return (0.05 * rng.standard_normal(shape)).astype(np.float32)
    if s.init == "ones":
        return np.ones(shape, np.float32)
    # fan-based inits: he / glorot
    if len(shape) == 4:  # HWIO conv
        fan_in = shape[0] * shape[1] * shape[2]
        fan_out = shape[0] * shape[1] * shape[3]
    elif len(shape) == 2:
        fan_in, fan_out = shape[0], shape[1]
    else:
        fan_in = fan_out = max(1, int(np.prod(shape)))
    if s.init == "glorot":
        std = math.sqrt(2.0 / (fan_in + fan_out))
    else:  # he
        std = math.sqrt(2.0 / fan_in)
    return (std * rng.standard_normal(shape)).astype(np.float32)


# ---- shared layers ------------------------------------------------------
#
# Compute-path dispatch: CLOUDLESS_COMPUTE=pallas routes every matmul/conv
# FLOP through the L1 Pallas kernels (the TPU story and what the kernel
# test-suite exercises); CLOUDLESS_COMPUTE=xla uses the equivalent native
# XLA ops (numerically identical — asserted by tests/test_models.py).
#
# Why both exist: interpret=True Pallas (the only Pallas CPU PJRT can run)
# costs a few ms of masking/slicing machinery per pallas_call; a conv-heavy
# backward pass makes dozens of calls, which would put the reproduction's
# ~10^5 Rust-side training iterations out of CPU budget. The experiment
# artifacts therefore default to the XLA path for conv models and the
# Pallas path stays the verified TPU lowering (see DESIGN.md §Perf).

import os  # noqa: E402

from compile.kernels import bias_act, matmul  # noqa: E402


def compute_mode() -> str:
    mode = os.environ.get("CLOUDLESS_COMPUTE", "pallas")
    if mode not in ("pallas", "xla"):
        raise ValueError(f"CLOUDLESS_COMPUTE must be pallas|xla, got {mode!r}")
    return mode


_ACTS_JNP = {
    "linear": lambda x: x,
    "relu": lambda x: jnp.maximum(x, 0.0),
    "tanh": jnp.tanh,
    "gelu": jax.nn.gelu,
    "sigmoid": jax.nn.sigmoid,
}


def matmul2d(a, b):
    """Rank-2 matmul on the active compute path."""
    if compute_mode() == "pallas":
        return matmul(a, b)
    return jnp.matmul(a, b)


def dense(x, w, b, act: str = "linear"):
    """Dense layer: matmul + fused bias+activation on the active path."""
    if compute_mode() == "pallas":
        return bias_act(matmul(x, w), b, act=act)
    return _ACTS_JNP[act](jnp.matmul(x, w) + b)


def conv2d_im2col(x, w, b, stride: int = 1, padding: str = "SAME", act: str = "linear"):
    """2-D convolution. x: [B,H,W,Cin], w: [kh,kw,Cin,Cout] (HWIO).

    Pallas path: im2col (conv_general_dilated_patches is data movement;
    feature dim ordered (cin, kh, kw)) so all FLOPs land in the L1 matmul.
    XLA path: native lax.conv_general_dilated.
    """
    if compute_mode() == "xla":
        y = jax.lax.conv_general_dilated(
            x, w, (stride, stride), padding,
            dimension_numbers=("NHWC", "HWIO", "NHWC"),
        )
        return _ACTS_JNP[act](y + b)

    kh, kw, cin, cout = w.shape
    patches = jax.lax.conv_general_dilated_patches(
        x,
        filter_shape=(kh, kw),
        window_strides=(stride, stride),
        padding=padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )  # [B, Ho, Wo, cin*kh*kw]
    bsz, ho, wo, feat = patches.shape
    # Match the (cin, kh, kw) feature ordering: w -> [cin, kh, kw, cout].
    w_mat = jnp.transpose(w, (2, 0, 1, 3)).reshape(feat, cout)
    y = matmul(patches.reshape(bsz * ho * wo, feat), w_mat)
    y = bias_act(y, b, act=act)
    return y.reshape(bsz, ho, wo, cout)


def avg_pool(x, window: int = 2, stride: int = 2):
    """Average pooling (data movement; no FLOPs to speak of)."""
    return jax.lax.reduce_window(
        x, 0.0, jax.lax.add, (1, window, window, 1), (1, stride, stride, 1), "VALID"
    ) / float(window * window)


def softmax_xent(logits, labels, num_classes: int):
    """Mean cross-entropy + correct-prediction count."""
    logp = jax.nn.log_softmax(logits)
    ll = jnp.take_along_axis(logp, labels[:, None], axis=1)[:, 0]
    loss = -jnp.mean(ll)
    correct = jnp.sum((jnp.argmax(logits, axis=1) == labels).astype(jnp.float32))
    return loss, correct


def sigmoid_xent(logits, labels):
    """Binary cross-entropy on logits + accuracy count (labels f32 in {0,1})."""
    loss = jnp.mean(jnp.maximum(logits, 0) - logits * labels + jnp.log1p(jnp.exp(-jnp.abs(logits))))
    pred = (logits > 0).astype(jnp.float32)
    correct = jnp.sum((pred == labels).astype(jnp.float32))
    return loss, correct

"""DeepFM (CTR prediction) — the paper's communication-heavy workload
(~2.4 MB of gradients; dominated by embeddings, like the Frappe setup).

10 categorical fields, per-field vocab 1000. Three towers share the
embeddings:
  - first-order: per-feature scalar weights,
  - FM second-order: 0.5 * ((Σv)² - Σv²) over k-dim embeddings,
  - deep: MLP [F*k -> 512 -> 256 -> 1] on the concatenated embeddings
    (all matmuls on the L1 Pallas kernel).
Binary cross-entropy on the summed logit; accuracy stands in for the
paper's AUC (same monotone trend on the synthetic CTR data, see DESIGN.md
substitutions).
"""

from __future__ import annotations

import jax.numpy as jnp

from compile.models.common import Model, ParamSpec, dense, sigmoid_xent

NUM_FIELDS = 10
# Frappe has ~5.4k total features; at the reproduction's scaled-down
# sample counts (16k train) a 64-ids-per-field vocabulary keeps every id
# trained (~250 observations each). The embedding/MLP widths are sized so
# the gradient payload still lands at the paper's ~2.4 MB.
VOCAB_PER_FIELD = 64
EMBED_DIM = 32
HIDDEN = (768, 384)

_TOTAL_VOCAB = NUM_FIELDS * VOCAB_PER_FIELD

SPECS = (
    ParamSpec("fo_w", (_TOTAL_VOCAB,), "embed"),  # first-order weights
    ParamSpec("emb", (_TOTAL_VOCAB, EMBED_DIM), "embed"),
    ParamSpec("mlp1_w", (NUM_FIELDS * EMBED_DIM, HIDDEN[0])),
    ParamSpec("mlp1_b", (HIDDEN[0],), "zeros"),
    ParamSpec("mlp2_w", (HIDDEN[0], HIDDEN[1])),
    ParamSpec("mlp2_b", (HIDDEN[1],), "zeros"),
    ParamSpec("out_w", (HIDDEN[1], 1), "glorot"),
    ParamSpec("out_b", (1,), "zeros"),
    ParamSpec("bias", (1,), "zeros"),
)


def _flat_ids(x):
    """Offset per-field ids into the shared vocab table: [B, F] i32."""
    offsets = jnp.arange(NUM_FIELDS, dtype=jnp.int32) * VOCAB_PER_FIELD
    return x + offsets[None, :]


def apply(p, x):
    """x: [B, F] int32 (per-field category ids) -> logits [B]."""
    ids = _flat_ids(x)
    first = jnp.sum(jnp.take(p["fo_w"], ids, axis=0), axis=1)  # [B]
    v = jnp.take(p["emb"], ids, axis=0)  # [B, F, k]
    sum_v = jnp.sum(v, axis=1)
    fm = 0.5 * jnp.sum(sum_v * sum_v - jnp.sum(v * v, axis=1), axis=1)  # [B]
    h = v.reshape(v.shape[0], -1)
    h = dense(h, p["mlp1_w"], p["mlp1_b"], act="relu")
    h = dense(h, p["mlp2_w"], p["mlp2_b"], act="relu")
    deep = dense(h, p["out_w"], p["out_b"])[:, 0]  # [B]
    return first + fm + deep + p["bias"][0]


def loss_and_metrics(p, x, y):
    return sigmoid_xent(apply(p, x), y)


def build(batch_size: int = 256) -> Model:
    return Model(
        name="deepfm",
        specs=SPECS,
        loss_and_metrics=loss_and_metrics,
        batch_size=batch_size,
        x_shape=(NUM_FIELDS,),
        x_dtype="i32",
        y_dtype="f32",
        num_classes=2,
        meta={
            "vocab_sizes": [VOCAB_PER_FIELD] * NUM_FIELDS,
            "embed_dim": EMBED_DIM,
        },
    )

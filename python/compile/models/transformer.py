"""Decoder-only transformer LM — the end-to-end validation workload
(system-prompt requirement: train a ~100M-param transformer on a tiny
corpus through the full stack and log the loss curve).

Pre-LN GPT-style blocks; attention and MLP projections all route through
the L1 Pallas matmul kernel. Weight-tied output head. Configurable size:
`build()` gives the ~8M default, `build_100m()` the ~100M config
(d=768, L=14, h=12).
"""

from __future__ import annotations

import dataclasses
from typing import List

import jax
import jax.numpy as jnp

from compile.models.common import Model, ParamSpec, matmul2d


@dataclasses.dataclass(frozen=True)
class TransformerConfig:
    vocab: int = 512
    seq: int = 128
    d_model: int = 256
    n_layer: int = 8
    n_head: int = 8
    batch_size: int = 8

    @property
    def d_head(self) -> int:
        return self.d_model // self.n_head


def _specs(c: TransformerConfig) -> List[ParamSpec]:
    d = c.d_model
    specs = [
        ParamSpec("tok_emb", (c.vocab, d), "normal"),
        ParamSpec("pos_emb", (c.seq, d), "normal"),
    ]
    for i in range(c.n_layer):
        pre = f"l{i}"
        specs += [
            ParamSpec(f"{pre}_ln1_g", (d,), "ones"),
            ParamSpec(f"{pre}_ln1_b", (d,), "zeros"),
            ParamSpec(f"{pre}_qkv_w", (d, 3 * d), "glorot"),
            ParamSpec(f"{pre}_qkv_b", (3 * d,), "zeros"),
            ParamSpec(f"{pre}_proj_w", (d, d), "glorot"),
            ParamSpec(f"{pre}_proj_b", (d,), "zeros"),
            ParamSpec(f"{pre}_ln2_g", (d,), "ones"),
            ParamSpec(f"{pre}_ln2_b", (d,), "zeros"),
            ParamSpec(f"{pre}_fc1_w", (d, 4 * d), "glorot"),
            ParamSpec(f"{pre}_fc1_b", (4 * d,), "zeros"),
            ParamSpec(f"{pre}_fc2_w", (4 * d, d), "glorot"),
            ParamSpec(f"{pre}_fc2_b", (d,), "zeros"),
        ]
    specs += [ParamSpec("lnf_g", (d,), "ones"), ParamSpec("lnf_b", (d,), "zeros")]
    return specs


def _layer_norm(x, g, b, eps=1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * g + b


def _mm(x, w):
    """[.., d_in] @ [d_in, d_out] on the active compute path (rank-2 collapse)."""
    lead = x.shape[:-1]
    y = matmul2d(x.reshape(-1, x.shape[-1]), w)
    return y.reshape(*lead, w.shape[-1])


def _block(c: TransformerConfig, p, pre: str, h, mask):
    x = _layer_norm(h, p[f"{pre}_ln1_g"], p[f"{pre}_ln1_b"])
    qkv = _mm(x, p[f"{pre}_qkv_w"]) + p[f"{pre}_qkv_b"]
    q, k, v = jnp.split(qkv, 3, axis=-1)
    b, s, d = q.shape
    def heads(t):
        return t.reshape(b, s, c.n_head, c.d_head).transpose(0, 2, 1, 3)
    q, k, v = heads(q), heads(k), heads(v)
    att = jnp.einsum("bhqd,bhkd->bhqk", q, k) / jnp.sqrt(float(c.d_head))
    att = jnp.where(mask, att, -1e9)
    att = jax.nn.softmax(att, axis=-1)
    y = jnp.einsum("bhqk,bhkd->bhqd", att, v)
    y = y.transpose(0, 2, 1, 3).reshape(b, s, d)
    h = h + _mm(y, p[f"{pre}_proj_w"]) + p[f"{pre}_proj_b"]
    x = _layer_norm(h, p[f"{pre}_ln2_g"], p[f"{pre}_ln2_b"])
    x = jax.nn.gelu(_mm(x, p[f"{pre}_fc1_w"]) + p[f"{pre}_fc1_b"])
    return h + _mm(x, p[f"{pre}_fc2_w"]) + p[f"{pre}_fc2_b"]


def make_apply(c: TransformerConfig):
    def apply(p, x):
        """x: [B, S] int32 tokens -> logits [B, S, vocab]."""
        h = jnp.take(p["tok_emb"], x, axis=0) + p["pos_emb"][None, : x.shape[1]]
        mask = jnp.tril(jnp.ones((x.shape[1], x.shape[1]), bool))[None, None]
        for i in range(c.n_layer):
            h = _block(c, p, f"l{i}", h, mask)
        h = _layer_norm(h, p["lnf_g"], p["lnf_b"])
        return _mm(h, p["tok_emb"].T)  # weight-tied head

    return apply


def make_loss(c: TransformerConfig):
    apply = make_apply(c)

    def loss_and_metrics(p, x, y):
        logits = apply(p, x)
        logp = jax.nn.log_softmax(logits)
        ll = jnp.take_along_axis(logp, y[..., None], axis=-1)[..., 0]
        loss = -jnp.mean(ll)
        correct = jnp.sum((jnp.argmax(logits, -1) == y).astype(jnp.float32)) / y.shape[1]
        return loss, correct

    return loss_and_metrics


def build_config(c: TransformerConfig, name: str = "transformer") -> Model:
    return Model(
        name=name,
        specs=tuple(_specs(c)),
        loss_and_metrics=make_loss(c),
        batch_size=c.batch_size,
        x_shape=(c.seq,),
        x_dtype="i32",
        y_dtype="i32",
        num_classes=0,
        meta={"vocab": c.vocab, "seq": c.seq, "lm": True,
              "d_model": c.d_model, "n_layer": c.n_layer, "n_head": c.n_head},
    )


def build(batch_size: int = 8) -> Model:
    return build_config(TransformerConfig(batch_size=batch_size))


def build_100m(batch_size: int = 4) -> Model:
    c = TransformerConfig(vocab=2048, seq=256, d_model=768, n_layer=14,
                          n_head=12, batch_size=batch_size)
    return build_config(c, name="transformer100m")

"""ResNet-lite (32x32x3 -> 10 classes) — the paper's cost-reduced ResNet18
variant ("filters are cut down by a factor of 4"); we size the width so the
gradient payload lands at the paper's reported ~0.6 MB (~150k f32 params).

Structure: conv3x3 stem -> 3 stages x 1 basic residual block (widths w, 2w,
4w; stride-2 downsample entering stages 2/3) -> global average pool -> fc.
Norm-free (bias + relu, identity/projection skips): BatchNorm is stateful
and would leak state through the flat-parameter PS boundary; at this scale
He-init residual nets train fine without it. Convs run through the L1
Pallas matmul via im2col.
"""

from __future__ import annotations

from typing import List

import jax.numpy as jnp

from compile.models.common import (
    Model,
    ParamSpec,
    conv2d_im2col,
    dense,
    softmax_xent,
)

NUM_CLASSES = 10
X_SHAPE = (32, 32, 3)
WIDTH = 24  # ~157k params -> ~0.6 MB of f32 gradients, matching the paper


def _specs(w: int) -> List[ParamSpec]:
    specs = [
        ParamSpec("stem_w", (3, 3, 3, w)),
        ParamSpec("stem_b", (w,), "zeros"),
    ]
    cin = w
    for stage, mult in enumerate((1, 2, 4)):
        cout = w * mult
        pre = f"s{stage}"
        specs += [
            ParamSpec(f"{pre}_c1_w", (3, 3, cin, cout)),
            ParamSpec(f"{pre}_c1_b", (cout,), "zeros"),
            # Fixup-style small init on the residual branch's second conv:
            # without BatchNorm, He-init both convs makes the residual
            # stream (and the gradients) blow up at depth.
            ParamSpec(f"{pre}_c2_w", (3, 3, cout, cout), "normal"),
            ParamSpec(f"{pre}_c2_b", (cout,), "zeros"),
        ]
        if cin != cout:
            specs += [
                ParamSpec(f"{pre}_proj_w", (1, 1, cin, cout)),
                ParamSpec(f"{pre}_proj_b", (cout,), "zeros"),
            ]
        cin = cout
    specs += [
        # Zero-init classifier: logits start at 0, keeping the first
        # (stale, asynchronous) updates small — with He-init here the
        # async replicas drive each other's ReLUs dead at any usable lr.
        ParamSpec("fc_w", (4 * w, NUM_CLASSES), "zeros"),
        ParamSpec("fc_b", (NUM_CLASSES,), "zeros"),
    ]
    return specs


SPECS = tuple(_specs(WIDTH))


def apply(p, x):
    """x: [B, 32, 32, 3] -> logits [B, 10]."""
    h = conv2d_im2col(x, p["stem_w"], p["stem_b"], padding="SAME", act="relu")
    for stage in range(3):
        pre = f"s{stage}"
        stride = 1 if stage == 0 else 2
        y = conv2d_im2col(h, p[f"{pre}_c1_w"], p[f"{pre}_c1_b"], stride=stride,
                          padding="SAME", act="relu")
        y = conv2d_im2col(y, p[f"{pre}_c2_w"], p[f"{pre}_c2_b"], padding="SAME")
        if f"{pre}_proj_w" in p:
            h = conv2d_im2col(h, p[f"{pre}_proj_w"], p[f"{pre}_proj_b"], stride=stride,
                              padding="SAME")
        h = jnp.maximum(h + y, 0.0)
    h = jnp.mean(h, axis=(1, 2))  # global average pool -> [B, 4w]
    return dense(h, p["fc_w"], p["fc_b"])


def loss_and_metrics(p, x, y):
    return softmax_xent(apply(p, x), y, NUM_CLASSES)


def build(batch_size: int = 32) -> Model:
    return Model(
        name="resnet",
        specs=SPECS,
        loss_and_metrics=loss_and_metrics,
        batch_size=batch_size,
        x_shape=X_SHAPE,
        x_dtype="f32",
        y_dtype="i32",
        num_classes=NUM_CLASSES,
    )

"""L2 model registry: name -> flat-parameter `Model` (see models/common.py).

This is the single place `aot.py` and the tests look models up; the Rust
coordinator identifies models by the same names (they appear in the
artifact filenames and `{model}_meta.json`).
"""

from __future__ import annotations

from typing import Callable, Dict

from compile.models import deepfm, lenet, resnet, transformer
from compile.models.common import Model

_BUILDERS: Dict[str, Callable[[], Model]] = {
    "lenet": lenet.build,
    "resnet": resnet.build,
    "deepfm": deepfm.build,
    "transformer": transformer.build,
    "transformer100m": transformer.build_100m,
}

#: Models lowered by a bare `make artifacts` (transformer100m is opt-in:
#: its init vector alone is ~400 MB on disk).
DEFAULT_MODELS = ("lenet", "resnet", "deepfm", "transformer")


def list_models():
    return sorted(_BUILDERS)


def get_model(name: str) -> Model:
    try:
        return _BUILDERS[name]()
    except KeyError:
        raise KeyError(f"unknown model {name!r}; known: {list_models()}") from None

"""L2 perf analysis: HLO inspection of the lowered artifacts
(EXPERIMENTS.md §Perf).

Parses the HLO text under artifacts/ and reports, per entry point:
op-category counts (dot/conv/fusion/elementwise/data-movement), parameter
traffic, and flags possible redundant recomputation (duplicate expensive
ops with identical shapes is a heuristic smell, not proof).

Usage: python -m compile.perf_l2 [--artifacts ../artifacts]
"""

from __future__ import annotations

import argparse
import glob
import os
import re
from collections import Counter

EXPENSIVE = ("dot(", "dot-general(", "convolution(", "fusion(")


def analyze(path: str) -> dict:
    text = open(path).read()
    ops = Counter()
    expensive_sigs = Counter()
    for line in text.splitlines():
        line = line.strip()
        m = re.match(r"(?:ROOT )?%?[\w.\-]+ = (\S+?)\[", line)
        if not m:
            continue
        # op name appears after '=' as e.g. f32[64,10]{1,0} dot(...)
        m2 = re.search(r"\]\S*\s+([a-z\-]+)\(", line)
        if not m2:
            continue
        op = m2.group(1)
        ops[op] += 1
        if op in ("dot", "convolution", "fusion"):
            shape = re.match(r"(?:ROOT )?%?[\w.\-]+ = (\S+)\s", line)
            expensive_sigs[(op, shape.group(1) if shape else "?")] += 1
    dupes = {sig: c for sig, c in expensive_sigs.items() if c > 1}
    return {
        "ops": ops,
        "total": sum(ops.values()),
        "dupes": dupes,
        "bytes": len(text),
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--artifacts", default="../artifacts")
    args = ap.parse_args()
    for path in sorted(glob.glob(os.path.join(args.artifacts, "*_train_step.hlo.txt"))):
        name = os.path.basename(path)
        info = analyze(path)
        ops = info["ops"]
        interesting = {
            k: ops[k]
            for k in ("dot", "convolution", "fusion", "while", "add", "multiply",
                      "transpose", "reshape", "slice", "dynamic-slice", "pad")
            if ops.get(k)
        }
        print(f"{name}: {info['total']} ops, {info['bytes']/1e3:.0f} kB text")
        print(f"  {interesting}")
        if info["dupes"]:
            worst = sorted(info["dupes"].items(), key=lambda kv: -kv[1])[:4]
            print(f"  repeated expensive ops (recompute smell): {worst}")


if __name__ == "__main__":
    main()

"""L1 perf analysis: Pallas matmul block-shape sweep (EXPERIMENTS.md §Perf).

interpret=True gives CPU-numpy timings, which are NOT a TPU proxy — so the
primary outputs are *structural*: VMEM working-set bytes and MXU-lane
utilization estimates per block configuration, for the matmul shapes the
models actually run. Optional `--time` also measures interpret-mode
wallclock (useful only to confirm grid-minimization on this host).

Usage: python -m compile.perf_l1 [--time]
"""

from __future__ import annotations

import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from compile.kernels.matmul import (
    auto_blocks,
    matmul_pallas_raw,
    mxu_utilization_estimate,
    vmem_bytes,
    VMEM_BUDGET_BYTES,
)

# The matmul shapes on the models' hot paths (M, K, N).
SHAPES = [
    ("lenet fc1 (B=64)", 64, 400, 120),
    ("lenet conv2 im2col", 6400, 150, 16),
    ("resnet stage3 im2col", 2048, 864, 96),
    ("deepfm mlp1 (B=256)", 256, 320, 768),
    ("deepfm mlp2", 256, 768, 384),
    ("transformer qkv (B*S=1024)", 1024, 256, 768),
    ("transformer mlp1", 1024, 256, 1024),
    ("transformer head", 1024, 256, 512),
    ("square 1024", 1024, 1024, 1024),
]

CANDIDATE_BLOCKS = [(128, 128, 128), (256, 256, 256), (512, 512, 512), (1024, 1024, 512)]


def grid_steps(m, k, n, bm, bn, bk):
    ceil = lambda a, b: -(-a // b)
    return ceil(m, bm) * ceil(n, bn) * ceil(k, bk)


def main() -> None:
    do_time = "--time" in sys.argv[1:]
    print(f"VMEM budget: {VMEM_BUDGET_BYTES/1e6:.1f} MB (double-buffered A/B + f32 acc)")
    header = f"{'shape':<28} {'blocks (auto)':<18} {'grid':>5} {'VMEM':>9} {'MXU est':>8}"
    if do_time:
        header += f" {'t(auto)':>9} {'t(128^3)':>9}"
    print(header)
    for label, m, k, n in SHAPES:
        bm, bn, bk = auto_blocks(m, k, n)
        gs = grid_steps(m, k, n, bm, bn, bk)
        vb = vmem_bytes(bm, bn, bk)
        mxu = mxu_utilization_estimate(m, n, k, bm, bn, bk)
        row = (f"{label:<28} {f'{bm}x{bn}x{bk}':<18} {gs:>5} "
               f"{vb/1e6:>7.2f}MB {mxu:>7.1%}")
        if do_time:
            rng = np.random.default_rng(0)
            a = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
            b = jnp.asarray(rng.standard_normal((k, n)), jnp.float32)

            def bench(fn):
                fn(a, b).block_until_ready()
                t0 = time.time()
                for _ in range(3):
                    out = fn(a, b)
                out.block_until_ready()
                return (time.time() - t0) / 3

            t_auto = bench(jax.jit(lambda a, b: matmul_pallas_raw(a, b)))
            t_128 = bench(jax.jit(lambda a, b: matmul_pallas_raw(a, b, bm=128, bn=128, bk=128)))
            row += f" {t_auto*1e3:>7.1f}ms {t_128*1e3:>7.1f}ms"
        print(row)

    print("\nfixed-block comparison on square 1024 (structural):")
    m = k = n = 1024
    for bm, bn, bk in CANDIDATE_BLOCKS:
        gs = grid_steps(m, k, n, bm, bn, bk)
        vb = vmem_bytes(bm, bn, bk)
        fits = "fits" if vb <= VMEM_BUDGET_BYTES else "OVER"
        print(f"  {bm:>4}x{bn:<4}x{bk:<4} grid={gs:>4} vmem={vb/1e6:>6.2f}MB ({fits}) "
              f"mxu={mxu_utilization_estimate(m, n, k, bm, bn, bk):.1%}")


if __name__ == "__main__":
    main()

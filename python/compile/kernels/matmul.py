"""L1 Pallas matmul kernel — the compute hot-spot of every model in this repo.

Dense layers, the DeepFM deep tower, transformer attention/MLP projections and
(via im2col) convolutions all funnel through this kernel, so it is the single
hot-spot the paper's training plane spends its FLOPs in.

TPU mapping (see DESIGN.md §Hardware-Adaptation): the kernel tiles C[M,N] into
(bm, bn) output blocks resident in VMEM and marches over K in (bk,) slabs —
the BlockSpec index maps express the HBM->VMEM schedule that a GPU kernel
would express with threadblocks + shared memory. Block defaults are MXU-
aligned (128x128) and sized so a double-buffered A/B/C working set fits
comfortably in 16 MB VMEM. Accumulation is always f32 (MXU native), with the
output cast back to the input dtype (bf16 supported).

Lowered with interpret=True: CPU PJRT cannot execute Mosaic custom-calls, so
interpret mode (which lowers to plain HLO) is the correctness + interchange
path; real-TPU efficiency is estimated in DESIGN.md §7.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# VMEM budget the auto-tiler targets: double-buffered A/B slabs + resident
# f32 accumulator must fit a 16 MB VMEM with headroom.
VMEM_BUDGET_BYTES = 12 * 1024 * 1024


def _ceil_to(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def auto_blocks(m: int, k: int, n: int, budget: int = VMEM_BUDGET_BYTES):
    """Pick (bm, bn, bk) so the per-step working set fits the VMEM budget.

    Policy: prefer the whole problem as a single block (grid 1x1x1) when it
    fits — on TPU that is the zero-revisit schedule, and under interpret
    mode it also minimizes per-grid-step overhead (measured ~5 ms/step on
    this CPU, see EXPERIMENTS.md §Perf). Otherwise clamp to MXU-aligned
    1024/1024/512 tiles and shrink bm until the working set fits.
    """
    bm, bn, bk = _ceil_to(m, 8), _ceil_to(n, 8), _ceil_to(k, 8)
    if vmem_bytes(bm, bn, bk) <= budget:
        return bm, bn, bk
    bm, bn, bk = min(bm, 1024), min(bn, 1024), min(bk, 512)
    while vmem_bytes(bm, bn, bk) > budget and bm > 128:
        bm //= 2
    while vmem_bytes(bm, bn, bk) > budget and bn > 128:
        bn //= 2
    while vmem_bytes(bm, bn, bk) > budget and bk > 128:
        bk //= 2
    return bm, bn, bk


def _mm_kernel(a_ref, b_ref, o_ref):
    """One (i, j, k) grid step: o[i,j] += a[i,k] @ b[k,j], f32 accumulate."""

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def matmul_pallas_raw(a, b, *, bm=None, bn=None, bk=None):
    """Tiled Pallas matmul without autodiff support. a: [M,K], b: [K,N].

    Block sizes default to `auto_blocks` (VMEM-budgeted, grid-minimizing).
    Pads every dimension up to a block multiple (zero padding is exact for
    matmul) and slices the result back, so arbitrary shapes are supported.
    """
    if a.ndim != 2 or b.ndim != 2:
        raise ValueError(f"matmul_pallas expects rank-2 operands, got {a.shape} @ {b.shape}")
    m, k = a.shape
    k2, n = b.shape
    if k != k2:
        raise ValueError(f"contraction mismatch: {a.shape} @ {b.shape}")

    abm, abn, abk = auto_blocks(m, k, n)
    # Explicit overrides (block-shape sweep bench) still shrink to the
    # padded problem so tiny layers don't blow up the padding.
    bm = min(bm, _ceil_to(m, 8)) if bm else abm
    bn = min(bn, _ceil_to(n, 8)) if bn else abn
    bk = min(bk, _ceil_to(k, 8)) if bk else abk

    mp, kp, np_ = _ceil_to(m, bm), _ceil_to(k, bk), _ceil_to(n, bn)
    a_p = jnp.pad(a, ((0, mp - m), (0, kp - k)))
    b_p = jnp.pad(b, ((0, kp - k), (0, np_ - n)))

    # f32 accumulator block; cast at the end for bf16 inputs.
    out = pl.pallas_call(
        _mm_kernel,
        grid=(mp // bm, np_ // bn, kp // bk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,
    )(a_p, b_p)
    return out[:m, :n].astype(a.dtype)


@jax.custom_vjp
def matmul(a, b):
    """Differentiable Pallas matmul: C = A @ B.

    The VJP routes both cotangent contractions (dA = g·Bᵀ, dB = Aᵀ·g) back
    through the same Pallas kernel, so fwd *and* bwd FLOPs run on the L1
    hot path.
    """
    return matmul_pallas_raw(a, b)


def _matmul_fwd(a, b):
    return matmul_pallas_raw(a, b), (a, b)


def _matmul_bwd(res, g):
    a, b = res
    g = g.astype(a.dtype)
    da = matmul_pallas_raw(g, b.T)
    db = matmul_pallas_raw(a.T, g)
    return da, db


matmul.defvjp(_matmul_fwd, _matmul_bwd)


def vmem_bytes(bm: int, bn: int, bk: int, dtype_bytes: int = 4) -> int:
    """Estimated VMEM working set for one grid step, double-buffered inputs.

    A block (bm x bk) + B block (bk x bn), x2 for double buffering, plus the
    resident f32 accumulator block (bm x bn). Used by the §Perf analysis and
    the block-shape sweep bench.
    """
    return 2 * (bm * bk + bk * bn) * dtype_bytes + bm * bn * 4


def mxu_utilization_estimate(m: int, n: int, k: int, bm: int, bn: int, bk: int) -> float:
    """Fraction of MXU-issue slots doing useful work, from padding overhead.

    The MXU is a 128x128 systolic array; blocks aligned to 128 waste no
    lanes. Padding waste is (padded FLOPs - real FLOPs) / padded FLOPs.
    """
    mp, np_, kp = _ceil_to(m, bm), _ceil_to(n, bn), _ceil_to(k, bk)
    real = 2.0 * m * n * k
    padded = 2.0 * mp * np_ * kp
    lane = min(bm, 128) * min(bn, 128) / (128.0 * 128.0)
    return (real / padded) * min(1.0, lane)

"""L1: Pallas kernels for the paper's compute hot-spots (see DESIGN.md).

`matmul` is the differentiable tiled matmul every model's FLOPs flow
through; `elementwise` holds the fused bias+activation and the PS-side
vector ops (sgd_apply / model_average / grad_accumulate); `ref` is the
pure-jnp oracle suite.
"""

from compile.kernels.matmul import matmul, matmul_pallas_raw  # noqa: F401
from compile.kernels.elementwise import (  # noqa: F401
    bias_act,
    grad_accumulate,
    model_average,
    sgd_apply,
)

"""Pure-jnp oracles for every L1 Pallas kernel.

These are the correctness references the pytest suite asserts the kernels
against (assert_allclose); they are also what hypothesis sweeps compare to
across shapes and dtypes. Keep them boring: one obvious jnp expression each.
"""

import jax
import jax.numpy as jnp

_ACTS = {
    "linear": lambda x: x,
    "relu": lambda x: jnp.maximum(x, 0.0),
    "tanh": jnp.tanh,
    "gelu": jax.nn.gelu,
    "sigmoid": jax.nn.sigmoid,
}


def matmul(a, b):
    return jnp.matmul(a, b)


def bias_act(x, b, act: str = "relu"):
    return _ACTS[act](x + b)


def sgd_apply(p, g, lr):
    return p - lr * g


def model_average(a, b, w=0.5):
    return w * a + (1.0 - w) * b


def grad_accumulate(acc, g):
    return acc + g

"""L1 fused elementwise Pallas kernels.

- bias_act: fused bias-add + activation used by every dense layer, so the
  activation never round-trips through HBM between the matmul and the
  nonlinearity.
- sgd_apply: fused parameter update p <- p - lr*g (the PS-side hot op).
- model_average: weighted average of two flat parameter vectors (the MA
  strategy's PS-side update).
- grad_accumulate: acc <- acc + g (ASGD-GA's local merge).

All operate on flat vectors or row blocks, tiled so each block fits VMEM,
and are lowered interpret=True (see matmul.py docstring).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# 1-D kernels tile the vector into slabs of this many elements (f32: 2 MB).
VEC_BLOCK = 512 * 1024
# 2-D bias+act kernels tile rows so a block stays under this VMEM budget
# (grid-minimizing, same rationale as matmul.auto_blocks).
ROW_BLOCK_BUDGET_BYTES = 8 * 1024 * 1024

_ACTS = {
    "linear": lambda x: x,
    "relu": lambda x: jnp.maximum(x, 0.0),
    "tanh": jnp.tanh,
    "gelu": jax.nn.gelu,
    "sigmoid": jax.nn.sigmoid,
}


def _ceil_to(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def _bias_act_kernel(x_ref, b_ref, o_ref, *, act):
    o_ref[...] = _ACTS[act](x_ref[...] + b_ref[...])


def _bias_act_bwd_kernel(x_ref, b_ref, g_ref, o_ref, *, act):
    """Elementwise VJP: o = g * act'(x + b), act' via jax.vjp of the act."""
    z = x_ref[...] + b_ref[...]
    _, vjp = jax.vjp(_ACTS[act], z)
    (dz,) = vjp(g_ref[...])
    o_ref[...] = dz


def _row_tiled(kernel, arrays, n_cols, out_dtype, act):
    """Run a row-blocked elementwise kernel over [M, N] arrays (+[N] bias)."""
    m = arrays[0].shape[0]
    rows_cap = max(256, ROW_BLOCK_BUDGET_BYTES // max(1, 4 * n_cols))
    bm = min(_ceil_to(rows_cap, 8), _ceil_to(m, 8))
    mp = _ceil_to(m, bm)
    padded, specs = [], []
    for a in arrays:
        if a.ndim == 2:
            padded.append(jnp.pad(a, ((0, mp - m), (0, 0))))
            specs.append(pl.BlockSpec((bm, n_cols), lambda i: (i, 0)))
        else:  # bias row, broadcast to every block
            padded.append(a)
            specs.append(pl.BlockSpec((n_cols,), lambda i: (0,)))
    out = pl.pallas_call(
        functools.partial(kernel, act=act),
        grid=(mp // bm,),
        in_specs=specs,
        out_specs=pl.BlockSpec((bm, n_cols), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((mp, n_cols), out_dtype),
        interpret=True,
    )(*padded)
    return out[:m]


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def bias_act(x, b, act: str = "relu"):
    """Fused o = act(x + b) for x: [M, N], b: [N] (differentiable)."""
    if act not in _ACTS:
        raise ValueError(f"unknown activation {act!r}")
    return _row_tiled(_bias_act_kernel, [x, b], x.shape[1], x.dtype, act)


def _bias_act_fwd(x, b, act):
    return bias_act(x, b, act), (x, b)


def _bias_act_bwd(act, res, g):
    x, b = res
    dx = _row_tiled(_bias_act_bwd_kernel, [x, b, g], x.shape[1], x.dtype, act)
    return dx, jnp.sum(dx, axis=0)


bias_act.defvjp(_bias_act_fwd, _bias_act_bwd)


def _sgd_kernel(p_ref, g_ref, lr_ref, o_ref):
    o_ref[...] = p_ref[...] - lr_ref[0] * g_ref[...]


@jax.jit
def sgd_apply(p, g, lr):
    """Fused p' = p - lr * g over a flat f32[P] vector."""
    (n,) = p.shape
    blk = min(VEC_BLOCK, _ceil_to(n, 8))
    np_ = _ceil_to(n, blk)
    p_p = jnp.pad(p, (0, np_ - n))
    g_p = jnp.pad(g, (0, np_ - n))
    lr_v = jnp.asarray(lr, p.dtype).reshape((1,))
    out = pl.pallas_call(
        _sgd_kernel,
        grid=(np_ // blk,),
        in_specs=[
            pl.BlockSpec((blk,), lambda i: (i,)),
            pl.BlockSpec((blk,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((blk,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((np_,), p.dtype),
        interpret=True,
    )(p_p, g_p, lr_v)
    return out[:n]


def _avg_kernel(a_ref, b_ref, w_ref, o_ref):
    w = w_ref[0]
    o_ref[...] = w * a_ref[...] + (1.0 - w) * b_ref[...]


@jax.jit
def model_average(a, b, w=0.5):
    """Fused o = w*a + (1-w)*b over flat f32[P] vectors (inter-PS MA update)."""
    (n,) = a.shape
    blk = min(VEC_BLOCK, _ceil_to(n, 8))
    np_ = _ceil_to(n, blk)
    a_p = jnp.pad(a, (0, np_ - n))
    b_p = jnp.pad(b, (0, np_ - n))
    w_v = jnp.asarray(w, a.dtype).reshape((1,))
    out = pl.pallas_call(
        _avg_kernel,
        grid=(np_ // blk,),
        in_specs=[
            pl.BlockSpec((blk,), lambda i: (i,)),
            pl.BlockSpec((blk,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((blk,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((np_,), a.dtype),
        interpret=True,
    )(a_p, b_p, w_v)
    return out[:n]


def _acc_kernel(a_ref, g_ref, o_ref):
    o_ref[...] = a_ref[...] + g_ref[...]


@jax.jit
def grad_accumulate(acc, g):
    """Fused acc' = acc + g over flat f32[P] vectors (ASGD-GA local merge)."""
    (n,) = acc.shape
    blk = min(VEC_BLOCK, _ceil_to(n, 8))
    np_ = _ceil_to(n, blk)
    a_p = jnp.pad(acc, (0, np_ - n))
    g_p = jnp.pad(g, (0, np_ - n))
    out = pl.pallas_call(
        _acc_kernel,
        grid=(np_ // blk,),
        in_specs=[
            pl.BlockSpec((blk,), lambda i: (i,)),
            pl.BlockSpec((blk,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((blk,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((np_,), acc.dtype),
        interpret=True,
    )(a_p, g_p)
    return out[:n]

"""L1 kernel correctness: every Pallas kernel vs the pure-jnp oracle.

Deterministic cases assert tight tolerances; hypothesis sweeps shapes and
dtypes (the CORE correctness signal for the kernels that end up in the
shipped HLO artifacts).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import (
    bias_act,
    grad_accumulate,
    matmul,
    matmul_pallas_raw,
    model_average,
    ref,
    sgd_apply,
)
from compile.kernels.matmul import auto_blocks, mxu_utilization_estimate, vmem_bytes

SETTINGS = dict(max_examples=25, deadline=None)


def _rand(rng, *shape, dtype=np.float32):
    return jnp.asarray(rng.standard_normal(shape).astype(dtype))


# ---------------------------------------------------------------- matmul


class TestMatmul:
    def test_square(self):
        rng = np.random.default_rng(0)
        a, b = _rand(rng, 64, 64), _rand(rng, 64, 64)
        np.testing.assert_allclose(matmul(a, b), ref.matmul(a, b), rtol=1e-5, atol=1e-5)

    def test_ragged_shapes(self):
        rng = np.random.default_rng(1)
        for m, k, n in [(1, 1, 1), (3, 5, 7), (130, 70, 10), (257, 129, 33)]:
            a, b = _rand(rng, m, k), _rand(rng, k, n)
            np.testing.assert_allclose(
                matmul(a, b), ref.matmul(a, b), rtol=1e-4, atol=1e-4,
                err_msg=f"shape ({m},{k},{n})")

    def test_explicit_blocks(self):
        rng = np.random.default_rng(2)
        a, b = _rand(rng, 100, 60), _rand(rng, 60, 40)
        for blk in (16, 32, 128):
            got = matmul_pallas_raw(a, b, bm=blk, bn=blk, bk=blk)
            np.testing.assert_allclose(got, ref.matmul(a, b), rtol=1e-4, atol=1e-4)

    def test_grad_matches_ref(self):
        rng = np.random.default_rng(3)
        a, b = _rand(rng, 17, 23), _rand(rng, 23, 11)

        def f_pl(a, b):
            return jnp.sum(jnp.sin(matmul(a, b)))

        def f_ref(a, b):
            return jnp.sum(jnp.sin(ref.matmul(a, b)))

        ga = jax.grad(f_pl, (0, 1))(a, b)
        gr = jax.grad(f_ref, (0, 1))(a, b)
        np.testing.assert_allclose(ga[0], gr[0], rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(ga[1], gr[1], rtol=1e-4, atol=1e-4)

    def test_bf16(self):
        rng = np.random.default_rng(4)
        a = _rand(rng, 32, 48).astype(jnp.bfloat16)
        b = _rand(rng, 48, 16).astype(jnp.bfloat16)
        got = matmul(a, b).astype(np.float32)
        want = ref.matmul(a, b).astype(np.float32)
        np.testing.assert_allclose(got, want, rtol=3e-2, atol=3e-2)

    def test_rank_check(self):
        with pytest.raises(ValueError):
            matmul_pallas_raw(jnp.zeros((2, 2, 2)), jnp.zeros((2, 2)))

    def test_contraction_check(self):
        with pytest.raises(ValueError):
            matmul_pallas_raw(jnp.zeros((2, 3)), jnp.zeros((4, 2)))

    @settings(**SETTINGS)
    @given(
        m=st.integers(1, 150),
        k=st.integers(1, 150),
        n=st.integers(1, 150),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_hypothesis_shapes_f32(self, m, k, n, seed):
        rng = np.random.default_rng(seed)
        a, b = _rand(rng, m, k), _rand(rng, k, n)
        np.testing.assert_allclose(matmul(a, b), ref.matmul(a, b), rtol=2e-4, atol=2e-4)

    @settings(**SETTINGS)
    @given(
        m=st.integers(1, 64),
        k=st.integers(1, 64),
        n=st.integers(1, 64),
        dtype=st.sampled_from(["float32", "bfloat16"]),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_hypothesis_dtypes(self, m, k, n, dtype, seed):
        rng = np.random.default_rng(seed)
        a = _rand(rng, m, k).astype(dtype)
        b = _rand(rng, k, n).astype(dtype)
        tol = 1e-4 if dtype == "float32" else 6e-2
        got = matmul(a, b).astype(np.float32)
        want = ref.matmul(a, b).astype(np.float32)
        np.testing.assert_allclose(got, want, rtol=tol, atol=tol)


class TestAutoBlocks:
    def test_small_is_single_block(self):
        bm, bn, bk = auto_blocks(64, 64, 64)
        assert (bm, bn, bk) == (64, 64, 64)

    def test_budget_respected(self):
        for m, k, n in [(4096, 4096, 4096), (100_000, 216, 24), (8, 10_000_000, 8)]:
            bm, bn, bk = auto_blocks(m, k, n)
            assert vmem_bytes(bm, bn, bk) <= 12 * 1024 * 1024, (m, k, n)

    def test_blocks_are_8_aligned(self):
        for m, k, n in [(3, 5, 7), (1000, 300, 77), (129, 257, 513)]:
            bm, bn, bk = auto_blocks(m, k, n)
            assert bm % 8 == 0 and bn % 8 == 0 and bk % 8 == 0

    def test_mxu_estimate_bounds(self):
        u = mxu_utilization_estimate(128, 128, 128, 128, 128, 128)
        assert u == pytest.approx(1.0)
        u2 = mxu_utilization_estimate(100, 100, 100, 128, 128, 128)
        assert 0.0 < u2 < 1.0


# ----------------------------------------------------------- elementwise


class TestBiasAct:
    @pytest.mark.parametrize("act", ["linear", "relu", "tanh", "gelu", "sigmoid"])
    def test_forward(self, act):
        rng = np.random.default_rng(5)
        x, b = _rand(rng, 33, 17), _rand(rng, 17)
        np.testing.assert_allclose(
            bias_act(x, b, act), ref.bias_act(x, b, act), rtol=1e-5, atol=1e-5)

    @pytest.mark.parametrize("act", ["relu", "tanh", "gelu", "sigmoid"])
    def test_grad(self, act):
        rng = np.random.default_rng(6)
        x, b = _rand(rng, 9, 13), _rand(rng, 13)
        g = jax.grad(lambda x, b: jnp.sum(bias_act(x, b, act) ** 2), (0, 1))(x, b)
        gr = jax.grad(lambda x, b: jnp.sum(ref.bias_act(x, b, act) ** 2), (0, 1))(x, b)
        np.testing.assert_allclose(g[0], gr[0], rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(g[1], gr[1], rtol=1e-4, atol=1e-4)

    def test_unknown_act_raises(self):
        with pytest.raises(ValueError):
            bias_act(jnp.zeros((2, 2)), jnp.zeros((2,)), "swish")

    @settings(**SETTINGS)
    @given(
        m=st.integers(1, 300),
        n=st.integers(1, 80),
        act=st.sampled_from(["linear", "relu", "tanh", "gelu", "sigmoid"]),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_hypothesis(self, m, n, act, seed):
        rng = np.random.default_rng(seed)
        x, b = _rand(rng, m, n), _rand(rng, n)
        np.testing.assert_allclose(
            bias_act(x, b, act), ref.bias_act(x, b, act), rtol=1e-4, atol=1e-4)


class TestVecOps:
    def test_sgd_apply(self):
        rng = np.random.default_rng(7)
        p, g = _rand(rng, 10_001), _rand(rng, 10_001)
        np.testing.assert_allclose(
            sgd_apply(p, g, 0.05), ref.sgd_apply(p, g, 0.05), rtol=1e-6, atol=1e-6)

    def test_model_average(self):
        rng = np.random.default_rng(8)
        a, b = _rand(rng, 4097), _rand(rng, 4097)
        np.testing.assert_allclose(
            model_average(a, b, 0.25), ref.model_average(a, b, 0.25), rtol=1e-6, atol=1e-6)

    def test_model_average_default_half(self):
        rng = np.random.default_rng(9)
        a, b = _rand(rng, 100), _rand(rng, 100)
        np.testing.assert_allclose(model_average(a, b), (a + b) / 2, rtol=1e-6, atol=1e-6)

    def test_grad_accumulate(self):
        rng = np.random.default_rng(10)
        acc, g = _rand(rng, 777), _rand(rng, 777)
        np.testing.assert_allclose(
            grad_accumulate(acc, g), ref.grad_accumulate(acc, g), rtol=1e-6, atol=1e-6)

    def test_accumulate_chain_equals_sum(self):
        """ASGD-GA invariant: accumulating k gradients == their sum."""
        rng = np.random.default_rng(11)
        gs = [_rand(rng, 501) for _ in range(5)]
        acc = jnp.zeros(501)
        for g in gs:
            acc = grad_accumulate(acc, g)
        np.testing.assert_allclose(acc, sum(gs), rtol=1e-5, atol=1e-5)

    @settings(**SETTINGS)
    @given(n=st.integers(1, 100_000), lr=st.floats(0.0, 1.0), seed=st.integers(0, 2**31 - 1))
    def test_hypothesis_sgd(self, n, lr, seed):
        rng = np.random.default_rng(seed)
        p, g = _rand(rng, n), _rand(rng, n)
        np.testing.assert_allclose(
            sgd_apply(p, g, lr), ref.sgd_apply(p, g, lr), rtol=1e-5, atol=1e-5)

    @settings(**SETTINGS)
    @given(n=st.integers(1, 50_000), w=st.floats(0.0, 1.0), seed=st.integers(0, 2**31 - 1))
    def test_hypothesis_average(self, n, w, seed):
        rng = np.random.default_rng(seed)
        a, b = _rand(rng, n), _rand(rng, n)
        np.testing.assert_allclose(
            model_average(a, b, w), ref.model_average(a, b, w), rtol=1e-5, atol=1e-5)

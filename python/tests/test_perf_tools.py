"""Smoke tests for the §Perf analysis tools (structure, not timing)."""

from compile.kernels.matmul import auto_blocks, vmem_bytes, VMEM_BUDGET_BYTES
from compile.perf_l2 import analyze
import os


def test_auto_blocks_minimize_grid_for_model_shapes():
    # Every model-hot-path shape should land in a handful of grid steps.
    for m, k, n in [(64, 400, 120), (6400, 150, 16), (1024, 256, 768), (256, 320, 768)]:
        bm, bn, bk = auto_blocks(m, k, n)
        ceil = lambda a, b: -(-a // b)
        grid = ceil(m, bm) * ceil(n, bn) * ceil(k, bk)
        assert grid <= 8, f"shape {(m,k,n)} got grid {grid}"
        assert vmem_bytes(bm, bn, bk) <= VMEM_BUDGET_BYTES


def test_hlo_analysis_finds_expensive_ops(tmp_path):
    # analyze() must count dots in a real artifact if present, otherwise
    # on a synthetic snippet.
    snippet = """HloModule m
ENTRY e {
  %p0 = f32[4,4]{1,0} parameter(0)
  %d1 = f32[4,4]{1,0} dot(%p0, %p0), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %d2 = f32[4,4]{1,0} dot(%d1, %p0), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  ROOT %a = f32[4,4]{1,0} add(%d1, %d2)
}
"""
    p = tmp_path / "toy.hlo.txt"
    p.write_text(snippet)
    info = analyze(str(p))
    assert info["ops"]["dot"] == 2
    assert info["ops"]["add"] == 1
    assert (("dot", "f32[4,4]{1,0}") in info["dupes"])


def test_hlo_analysis_on_real_artifact():
    path = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts",
                        "lenet_train_step.hlo.txt")
    if not os.path.exists(path):
        return  # artifacts not built in this checkout
    info = analyze(path)
    assert info["ops"]["dot"] >= 6  # fwd+bwd fc layers
    assert info["ops"]["convolution"] >= 4

"""AOT pipeline checks: HLO text artifacts are well-formed and consistent.

Lowers the smallest model (lenet) + the kernel demo into a temp dir and
validates: HLO text parses structurally, metadata matches the model, the
init vector has the advertised length, and vecop artifacts exist. (The
Rust integration tests then prove the artifacts actually execute through
PJRT.)
"""

import json
import os

import numpy as np
import pytest

from compile.aot import lower_kernel_demo, lower_model, to_hlo_text
from compile.model import get_model


@pytest.fixture(scope="module")
def lowered_dir(tmp_path_factory):
    out = str(tmp_path_factory.mktemp("artifacts"))
    lower_model(get_model("lenet"), out, seed=0, verbose=False)
    lower_kernel_demo(out, n=32, verbose=False)
    return out


def test_artifact_files_exist(lowered_dir):
    for suffix in ("train_step.hlo.txt", "eval.hlo.txt", "sgd_apply.hlo.txt",
                   "avg.hlo.txt", "acc.hlo.txt", "init.bin", "meta.json"):
        path = os.path.join(lowered_dir, f"lenet_{suffix}")
        assert os.path.exists(path), path
        assert os.path.getsize(path) > 0, path
    assert os.path.exists(os.path.join(lowered_dir, "kernel_matmul.hlo.txt"))


def test_hlo_text_structure(lowered_dir):
    text = open(os.path.join(lowered_dir, "lenet_train_step.hlo.txt")).read()
    assert text.startswith("HloModule"), "must be HLO text, not a proto dump"
    assert "ENTRY" in text
    # flat-parameter convention: first operand is f32[P]
    meta = json.load(open(os.path.join(lowered_dir, "lenet_meta.json")))
    assert f"f32[{meta['param_count']}]" in text


def test_meta_consistency(lowered_dir):
    meta = json.load(open(os.path.join(lowered_dir, "lenet_meta.json")))
    m = get_model("lenet")
    assert meta["param_count"] == m.param_count
    assert meta["batch_size"] == m.batch_size
    assert meta["x_shape"] == list(m.x_shape)
    assert meta["param_bytes"] == m.param_count * 4
    assert sum(int(np.prod(s["shape"])) for s in meta["specs"]) == m.param_count


def test_init_bin_length_and_determinism(lowered_dir):
    m = get_model("lenet")
    init = np.fromfile(os.path.join(lowered_dir, "lenet_init.bin"), dtype=np.float32)
    assert init.shape == (m.param_count,)
    np.testing.assert_allclose(init, m.init_flat(0))
    assert np.all(np.isfinite(init))


def test_vecops_are_pallas_lowered(lowered_dir):
    """Vecop artifacts come from pallas_call -> while-loop HLO structure."""
    text = open(os.path.join(lowered_dir, "lenet_sgd_apply.hlo.txt")).read()
    assert text.startswith("HloModule")
    m = get_model("lenet")
    assert f"f32[{m.param_count}]" in text


def test_to_hlo_text_roundtrips_simple_fn():
    import jax
    import jax.numpy as jnp

    lowered = jax.jit(lambda a, b: (a @ b,)).lower(
        jax.ShapeDtypeStruct((4, 4), jnp.float32),
        jax.ShapeDtypeStruct((4, 4), jnp.float32),
    )
    text = to_hlo_text(lowered)
    assert text.startswith("HloModule")
    assert "ENTRY" in text

"""L2 model checks: shapes, gradient correctness, path equivalence, learning.

- every model produces finite loss + a full-length gradient vector;
- the pallas and xla compute paths agree numerically (the property that
  lets the artifacts ship either path, see models/common.py);
- finite-difference gradient check on a downsized model;
- a few SGD steps on a fixed batch reduce the loss (learnability smoke).
"""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.model import DEFAULT_MODELS, get_model, list_models

ALL = list(DEFAULT_MODELS)


@pytest.fixture(autouse=True)
def _pallas_mode():
    """Tests default to the pallas path unless they set it themselves."""
    old = os.environ.get("CLOUDLESS_COMPUTE")
    os.environ["CLOUDLESS_COMPUTE"] = "pallas"
    yield
    if old is None:
        os.environ.pop("CLOUDLESS_COMPUTE", None)
    else:
        os.environ["CLOUDLESS_COMPUTE"] = old


def test_registry():
    assert set(ALL) <= set(list_models())
    with pytest.raises(KeyError):
        get_model("nope")


@pytest.mark.parametrize("name", ALL)
def test_shapes_and_finite(name):
    m = get_model(name)
    flat = jnp.asarray(m.init_flat(0))
    assert flat.shape == (m.param_count,)
    x, y = m.example_batch()
    g, loss = jax.jit(m.train_step)(flat, x, y)
    assert g.shape == (m.param_count,)
    assert np.isfinite(float(loss))
    assert np.all(np.isfinite(np.asarray(g)))
    loss_sum, correct = jax.jit(m.eval_step)(flat, x, y)
    assert np.isfinite(float(loss_sum))
    assert 0.0 <= float(correct) <= m.batch_size + 1e-6


@pytest.mark.parametrize("name", ALL)
def test_compute_paths_agree(name):
    m = get_model(name)
    flat = jnp.asarray(m.init_flat(0))
    x, y = m.example_batch()
    outs = {}
    for mode in ("pallas", "xla"):
        os.environ["CLOUDLESS_COMPUTE"] = mode
        outs[mode] = jax.jit(m.train_step)(flat, x, y)
    np.testing.assert_allclose(
        outs["pallas"][1], outs["xla"][1], rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(
        outs["pallas"][0], outs["xla"][0], rtol=5e-3, atol=1e-4)


def test_finite_difference_grad_lenet():
    """Spot-check d(loss)/d(param) against central differences."""
    m = get_model("lenet")
    flat = jnp.asarray(m.init_flat(3))
    x, y = m.example_batch(3)
    g, _ = jax.jit(m.train_step)(flat, x, y)
    loss = jax.jit(m.loss_flat)
    rng = np.random.default_rng(0)
    idxs = rng.integers(0, m.param_count, size=6)
    eps = 1e-3
    for i in idxs:
        e = jnp.zeros_like(flat).at[i].set(eps)
        fd = (float(loss(flat + e, x, y)) - float(loss(flat - e, x, y))) / (2 * eps)
        assert abs(fd - float(g[i])) < 5e-3, f"param {i}: fd={fd} ad={float(g[i])}"


@pytest.mark.parametrize("name", ALL)
def test_sgd_reduces_loss(name):
    """A few full-batch SGD steps on one batch must reduce the loss."""
    os.environ["CLOUDLESS_COMPUTE"] = "xla"  # speed; equivalence tested above
    m = get_model(name)
    flat = jnp.asarray(m.init_flat(1))
    x, y = m.example_batch(1)
    step = jax.jit(m.train_step)
    lr = {"lenet": 0.05, "resnet": 0.01, "deepfm": 0.05, "transformer": 0.05}[name]
    g, loss0 = step(flat, x, y)
    for _ in range(8):
        g, loss = step(flat, x, y)
        flat = flat - lr * g
    _, loss1 = step(flat, x, y)
    assert float(loss1) < float(loss0), f"{name}: {float(loss0)} -> {float(loss1)}"


def test_param_count_matches_paper_scale():
    """Gradient payloads should land near the paper's reported sizes."""
    sizes = {n: get_model(n).param_count * 4 / 1e6 for n in ("lenet", "resnet", "deepfm")}
    assert 0.1 < sizes["lenet"] < 0.5       # paper: 0.4 MB
    assert 0.4 < sizes["resnet"] < 1.0      # paper: 0.6 MB
    assert 1.5 < sizes["deepfm"] < 3.5      # paper: 2.4 MB


def test_example_batch_deterministic():
    m = get_model("lenet")
    x1, y1 = m.example_batch(7)
    x2, y2 = m.example_batch(7)
    np.testing.assert_array_equal(x1, x2)
    np.testing.assert_array_equal(y1, y2)


def test_unflatten_roundtrip():
    m = get_model("lenet")
    flat = jnp.asarray(m.init_flat(0))
    tree = m.unflatten(flat)
    assert set(tree) == {s.name for s in m.specs}
    re_flat = m.flatten(tree, m.specs)
    np.testing.assert_allclose(re_flat, flat)


def test_transformer_100m_config_size():
    m = get_model("transformer100m")
    assert 80e6 < m.param_count < 130e6, m.param_count
